"""Network channels and transports: the simulated byte fabric, and the
real message transports the fleet's control plane runs over.

Two layers live here:

  * the *simulated* byte fabric (``Channel``/``Fabric``): latency /
    bandwidth / packet loss modelled against a deterministic
    ``SimClock`` (benchmarks read transfer time off the clock; compute
    time is real wall time).  Everything above the byte layer -- the
    attested TLS-style handshake, session-key binding, chunked transfer
    with integrity, multi-hop transitive chains -- is real protocol
    code and is what the security tests exercise.  Link conditions are
    properties of the *path*: ``Fabric.path`` composes the per-pair
    condition with each endpoint's own uplink condition (latencies add,
    bandwidth is the min, loss compounds, up = every segment up), so a
    lossy edge uplink degrades every pair that crosses it.

  * the *message transport* (``Transport``): the frame fabric the
    fleet's control plane and engine services exchange control,
    migration and heartbeat messages over.  ``InProcTransport`` is the
    deterministic test transport (synchronous in-process delivery, the
    bit-exactness contracts hold here); ``SocketTransport`` is real
    loopback TCP -- length-prefixed frames, one listener per node, one
    cached connection per (src, dst) pair -- so migrations and
    heartbeats are genuinely overlapped in-flight bytes.  Both support
    sender-side fault injection (drop / delay / peer death) for the
    chaos suites.
"""

from __future__ import annotations

import os
import socket
import struct
import threading
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core import crypto
from repro.core.attestation import Attester, Quote


class SimClock:
    def __init__(self, t0: float = 0.0):
        self.t = t0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float):
        self.t += dt


@dataclass
class NetworkCondition:
    latency_s: float = 0.02          # one-way
    bandwidth_bps: float = 1e9       # paper's 1 Gbps migration link
    loss: float = 0.0                # packet loss fraction
    up: bool = True

    def transfer_time(self, nbytes: int) -> float:
        if not self.up:
            return float("inf")
        eff = self.bandwidth_bps * (1.0 - min(self.loss, 0.99)) / 8.0
        retrans = 1.0 / (1.0 - min(self.loss, 0.99))
        return self.latency_s + nbytes / eff * retrans


@dataclass
class Channel:
    """Byte pipe with simulated timing.  ``taps`` lets tests play the
    network adversary (record / tamper with ciphertext)."""
    cond: NetworkCondition = field(default_factory=NetworkCondition)
    clock: SimClock = field(default_factory=SimClock)
    taps: list = field(default_factory=list)
    bytes_sent: int = 0

    def send(self, data: bytes) -> bytes:
        if not self.cond.up:
            raise ConnectionError("network down")
        self.clock.advance(self.cond.transfer_time(len(data)))
        self.bytes_sent += len(data)
        for tap in self.taps:
            data = tap(data)
        return data


class ComposedCondition:
    """Effective condition of a multi-segment path.

    Latencies add, bandwidth is the narrowest segment, loss compounds
    (a packet survives only if it survives every segment), and the path
    is up only when every segment is up.  Duck-types
    ``NetworkCondition`` so channels, tier policy and router cost can
    consume either.
    """

    def __init__(self, *parts):
        self.parts = [p for p in parts if p is not None]

    @property
    def latency_s(self) -> float:
        return sum(p.latency_s for p in self.parts)

    @property
    def bandwidth_bps(self) -> float:
        return min((p.bandwidth_bps for p in self.parts), default=1e9)

    @property
    def loss(self) -> float:
        keep = 1.0
        for p in self.parts:
            keep *= 1.0 - min(p.loss, 0.99)
        return 1.0 - keep

    @property
    def up(self) -> bool:
        return all(p.up for p in self.parts)

    transfer_time = NetworkCondition.transfer_time


class PathCondition:
    """Live view of the path a<->b on a fabric: endpoint uplink of
    ``a``, the pair condition, endpoint uplink of ``b``, composed at
    read time so later ``set_link``/``set_endpoint`` calls are seen by
    channels already handed out.  ``endpoints=False`` reads only the
    pair segment -- a pinned circuit that ignores uplink outages."""

    def __init__(self, fabric: "Fabric", a: str, b: str, *,
                 endpoints: bool = True):
        self.fabric, self.a, self.b = fabric, a, b
        self.endpoints = endpoints

    def _now(self) -> ComposedCondition:
        if not self.endpoints:
            return ComposedCondition(self.fabric.pair_cond(self.a, self.b))
        return self.fabric.path(self.a, self.b)

    @property
    def latency_s(self) -> float:
        return self._now().latency_s

    @property
    def bandwidth_bps(self) -> float:
        return self._now().bandwidth_bps

    @property
    def loss(self) -> float:
        return self._now().loss

    @property
    def up(self) -> bool:
        return self._now().up

    transfer_time = NetworkCondition.transfer_time


class Fabric:
    """Cluster interconnect: one ``Channel`` per engine pair, all ticking
    the same ``SimClock`` so fleet-wide transfer timings compose.  Links
    default to ``default_cond`` until ``set_link`` gives a pair its own
    conditions (a lossy edge uplink next to a fast pod fabric).  Each
    node may additionally register its own uplink condition via
    ``set_endpoint``; ``path`` composes endpoint + pair + endpoint so
    conditions are properties of the route, not of a single global
    knob."""

    def __init__(self, default_cond: NetworkCondition | None = None):
        self.clock = SimClock()
        self.default_cond = default_cond or NetworkCondition()
        self._conds: dict[frozenset, NetworkCondition] = {}
        self._endpoints: dict[str, NetworkCondition] = {}
        self._links: dict[frozenset, Channel] = {}
        self._pair_links: dict[frozenset, Channel] = {}

    def set_link(self, a: str, b: str, cond: NetworkCondition):
        self._conds[frozenset((a, b))] = cond
        self._links.pop(frozenset((a, b)), None)

    def set_endpoint(self, name: str, cond: NetworkCondition | None):
        if cond is None:
            self._endpoints.pop(name, None)
        else:
            self._endpoints[name] = cond

    def endpoint(self, name: str) -> NetworkCondition | None:
        return self._endpoints.get(name)

    def pair_cond(self, a: str, b: str) -> NetworkCondition:
        return self._conds.get(frozenset((a, b)), self.default_cond)

    def path(self, a: str, b: str, *,
             end_a: NetworkCondition | None = None,
             end_b: NetworkCondition | None = None) -> ComposedCondition:
        """Effective condition of the a->b route: a's uplink, the pair
        link, b's uplink.  Explicit ``end_*`` override the registered
        endpoint conditions (the router passes a handle's tier uplink
        here)."""
        return ComposedCondition(
            end_a if end_a is not None else self._endpoints.get(a),
            self.pair_cond(a, b),
            end_b if end_b is not None else self._endpoints.get(b),
        )

    def link(self, a: str, b: str) -> Channel:
        key = frozenset((a, b))
        if key not in self._links:
            self._links[key] = Channel(cond=PathCondition(self, a, b),
                                       clock=self.clock)
        return self._links[key]

    def pair_link(self, a: str, b: str) -> Channel:
        """A pinned circuit between two co-provisioned engines (a
        draft/verify tier pair's dedicated interconnect): the channel
        reads only the live pair-level condition, so endpoint uplink
        outages -- which gate routing and client traffic -- do not sever
        an established intra-pair wire."""
        key = frozenset((a, b))
        if key not in self._pair_links:
            self._pair_links[key] = Channel(
                cond=PathCondition(self, a, b, endpoints=False),
                clock=self.clock)
        return self._pair_links[key]


class AttestedSession:
    """Mutually-attested session between two enclaves (paper §5).

    Handshake: exchange nonces -> exchange quotes (bound to nonces) ->
    verify signature/whitelist/freshness/counter/capabilities ->
    derive attestation-bound session key.  All payloads then travel
    sealed (encrypt-then-MAC) with the workload id as AAD."""

    def __init__(self, a: Attester, b: Attester, channel: Channel,
                 whitelist: set[str], need: frozenset[str] = frozenset()):
        self.channel = channel
        self.a, self.b = a, b
        nonce_a, nonce_b = os.urandom(8).hex(), os.urandom(8).hex()
        qa = a.quote(nonce_b)        # quote binds the peer's nonce
        qb = b.quote(nonce_a)
        # wire: quotes are public; taps may observe/modify them
        self.channel.send(qa.payload())
        self.channel.send(qb.payload())
        b.verify(a.enclave_id, qa, nonce=nonce_b, whitelist=whitelist,
                 need=need)
        a.verify(b.enclave_id, qb, nonce=nonce_a, whitelist=whitelist)
        self.key_a = a.session_key(b.enclave_id, qa, qb)
        self.key_b = b.session_key(a.enclave_id, qb, qa)
        assert self.key_a == self.key_b
        self.quotes = (qa, qb)

    def transfer(self, payload: bytes, aad: bytes = b"") -> bytes:
        """Seal on A, wire (taps may tamper), open on B."""
        sealed = crypto.seal(self.key_a, payload, aad)
        wired = self.channel.send(sealed)
        return crypto.open_(self.key_b, wired, aad)


def transitive_chain(hops: list[Attester], channel: Channel,
                     whitelist: set[str]) -> list[Quote]:
    """Multi-hop migration trust chain (paper §5): every adjacent pair
    performs mutual attestation; one bad hop poisons the chain."""
    quotes = []
    for src, dst in zip(hops, hops[1:]):
        s = AttestedSession(src, dst, channel, whitelist)
        quotes.extend(s.quotes)
    return quotes


# ---------------------------------------------------------------------------
# Message transports
# ---------------------------------------------------------------------------
#
# The fleet's control plane and engine services talk in framed messages.
# A transport moves opaque frames (bytes) between named nodes; the bus
# layer (fleet/bus.py) owns encoding.  Fault injection is sender-side
# and per-frame: a hook inspects (src, dst, payload) and returns
# None/"ok" (deliver), "drop" (silently lose the frame), or
# ("delay", seconds) (deliver late -- immediately into a hold queue on
# the in-proc transport, via a timer on the socket transport).

FaultHook = Callable[[str, str, bytes], object]


class Transport:
    """Frame fabric between named nodes."""

    def register(self, name: str, deliver: Callable[[bytes], None]) -> None:
        raise NotImplementedError

    def deregister(self, name: str) -> None:
        raise NotImplementedError

    def send(self, src: str, dst: str, payload: bytes) -> bool:
        """Hand one frame to the fabric.  Returns False when the
        destination is known-unreachable (dead peer); a True return is
        *not* a delivery guarantee -- frames may still be lost in
        flight.  Reliability lives above (RPC retry + idempotent
        receivers)."""
        raise NotImplementedError

    def close(self) -> None:
        pass


class InProcTransport(Transport):
    """Deterministic in-process transport: ``send`` delivers
    synchronously into the destination's handler on the caller's
    thread.  This is the transport the bit-exactness / conservation
    contracts are verified on.  Faulted "delay" frames park in
    ``held`` until the test calls ``release_held``."""

    def __init__(self):
        self._nodes: dict[str, Callable[[bytes], None]] = {}
        self.fault: Optional[FaultHook] = None
        self.held: list[tuple[str, str, bytes]] = []
        self.dropped: int = 0

    def register(self, name: str, deliver: Callable[[bytes], None]) -> None:
        self._nodes[name] = deliver

    def deregister(self, name: str) -> None:
        self._nodes.pop(name, None)

    def send(self, src: str, dst: str, payload: bytes) -> bool:
        if dst not in self._nodes:
            return False
        if self.fault is not None:
            verdict = self.fault(src, dst, payload)
            if verdict == "drop":
                self.dropped += 1
                return True
            if isinstance(verdict, tuple) and verdict and verdict[0] == "delay":
                self.held.append((src, dst, payload))
                return True
        self._nodes[dst](payload)
        return True

    def release_held(self) -> int:
        """Deliver every held frame (in order); returns how many."""
        held, self.held = self.held, []
        n = 0
        for src, dst, payload in held:
            deliver = self._nodes.get(dst)
            if deliver is not None:
                deliver(payload)
                n += 1
        return n


class SocketTransport(Transport):
    """Loopback TCP transport: one listener per node, frames are
    4-byte big-endian length prefix + payload, one cached outbound
    connection per (src, dst) pair.  Each accepted connection gets a
    reader thread that feeds complete frames to the node's handler, so
    a migration blob in flight never blocks another engine's decode
    loop."""

    MAX_FRAME = 64 * 1024 * 1024

    def __init__(self, host: str = "127.0.0.1"):
        self.host = host
        self._lock = threading.RLock()
        self._addrs: dict[str, tuple[str, int]] = {}
        self._servers: dict[str, socket.socket] = {}
        self._conns: dict[tuple[str, str], socket.socket] = {}
        self._threads: list[threading.Thread] = []
        self.fault: Optional[FaultHook] = None
        self.dropped = 0
        self._closed = False

    # -- wire helpers ------------------------------------------------
    @staticmethod
    def _send_frame(sock: socket.socket, payload: bytes) -> None:
        sock.sendall(struct.pack(">I", len(payload)) + payload)

    @classmethod
    def _recv_frame(cls, sock: socket.socket) -> bytes | None:
        hdr = cls._recv_exact(sock, 4)
        if hdr is None:
            return None
        (n,) = struct.unpack(">I", hdr)
        if n > cls.MAX_FRAME:
            raise ValueError(f"frame too large: {n} bytes")
        return cls._recv_exact(sock, n)

    @staticmethod
    def _recv_exact(sock: socket.socket, n: int) -> bytes | None:
        buf = b""
        while len(buf) < n:
            try:
                chunk = sock.recv(n - len(buf))
            except OSError:
                return None
            if not chunk:
                return None
            buf += chunk
        return buf

    # -- node lifecycle ----------------------------------------------
    def register(self, name: str, deliver: Callable[[bytes], None]) -> None:
        srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        srv.bind((self.host, 0))
        srv.listen(16)
        with self._lock:
            self._servers[name] = srv
            self._addrs[name] = srv.getsockname()
        t = threading.Thread(target=self._accept_loop,
                             args=(name, srv, deliver),
                             name=f"xport-accept-{name}", daemon=True)
        t.start()
        self._threads.append(t)

    def _accept_loop(self, name: str, srv: socket.socket,
                     deliver: Callable[[bytes], None]) -> None:
        while True:
            try:
                conn, _ = srv.accept()
            except OSError:
                return      # listener closed: node deregistered
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            t = threading.Thread(target=self._reader_loop,
                                 args=(conn, deliver),
                                 name=f"xport-read-{name}", daemon=True)
            t.start()
            self._threads.append(t)

    def _reader_loop(self, conn: socket.socket,
                     deliver: Callable[[bytes], None]) -> None:
        while True:
            try:
                frame = self._recv_frame(conn)
            except (OSError, ValueError):
                frame = None
            if frame is None:
                try:
                    conn.close()
                except OSError:
                    pass
                return
            deliver(frame)

    def deregister(self, name: str) -> None:
        """Kill the node: close its listener and every cached
        connection touching it.  In-flight frames to it are lost --
        exactly the peer-death fault the chaos suite exercises."""
        with self._lock:
            srv = self._servers.pop(name, None)
            self._addrs.pop(name, None)
            stale = [k for k in self._conns if name in k]
            socks = [self._conns.pop(k) for k in stale]
        if srv is not None:
            try:
                srv.close()
            except OSError:
                pass
        for s in socks:
            try:
                s.close()
            except OSError:
                pass

    # -- sending -----------------------------------------------------
    def _conn_to(self, src: str, dst: str) -> socket.socket | None:
        key = (src, dst)
        with self._lock:
            sock = self._conns.get(key)
            if sock is not None:
                return sock
            addr = self._addrs.get(dst)
        if addr is None:
            return None
        try:
            sock = socket.create_connection(addr, timeout=5.0)
        except OSError:
            return None
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        with self._lock:
            # lost the race to another sender thread: keep theirs
            if key in self._conns:
                try:
                    sock.close()
                except OSError:
                    pass
                return self._conns[key]
            self._conns[key] = sock
        return sock

    def send(self, src: str, dst: str, payload: bytes) -> bool:
        if self._closed:
            return False
        if self.fault is not None:
            verdict = self.fault(src, dst, payload)
            if verdict == "drop":
                self.dropped += 1
                return True
            if isinstance(verdict, tuple) and verdict and verdict[0] == "delay":
                delay_s = float(verdict[1])
                timer = threading.Timer(
                    delay_s, self._send_now, args=(src, dst, payload))
                timer.daemon = True
                timer.start()
                return True
        return self._send_now(src, dst, payload)

    def _send_now(self, src: str, dst: str, payload: bytes) -> bool:
        sock = self._conn_to(src, dst)
        if sock is None:
            return False
        try:
            with self._lock:
                self._send_frame(sock, payload)
            return True
        except OSError:
            with self._lock:
                self._conns.pop((src, dst), None)
            try:
                sock.close()
            except OSError:
                pass
            return False

    def close(self) -> None:
        self._closed = True
        with self._lock:
            servers = list(self._servers.values())
            conns = list(self._conns.values())
            self._servers.clear()
            self._conns.clear()
            self._addrs.clear()
        for s in servers + conns:
            try:
                s.close()
            except OSError:
                pass
