"""Privacy-aware daemon: the placement scheduler (paper §7.4, §9.4).

Decides local-vs-remote execution from
  (1) data-sensitivity policy -- confidential workloads never leave the
      local enclave unless the remote attests AND policy allows;
  (2) a roofline cost model of both endpoints -- decode is HBM-bound
      (active param bytes / bandwidth per token), prefill is MXU-bound
      (2*N_active*S FLOPs / peak);
  (3) migration amortization -- the paper's empirical rule: migrate only
      when remote speedup >= 1.5x and remaining work >= 2x migration time.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig
from repro.core.channel import NetworkCondition


@dataclass(frozen=True)
class DeviceProfile:
    name: str
    peak_flops: float                # bf16
    hbm_bw: float                    # bytes/s
    chips: int = 1
    attested: bool = True

    @property
    def agg_flops(self):
        return self.peak_flops * self.chips

    @property
    def agg_bw(self):
        return self.hbm_bw * self.chips


# edge = one M3-Max-class device; cloud = a v5e-pod-slice-class target;
# mcu = a Cortex-M-class endpoint with no enclave (never attested)
EDGE = DeviceProfile("edge", peak_flops=25e12, hbm_bw=400e9, chips=1)
CLOUD = DeviceProfile("cloud", peak_flops=197e12, hbm_bw=819e9, chips=8)
MCU = DeviceProfile("mcu", peak_flops=5e11, hbm_bw=25e9, chips=1,
                    attested=False)


@dataclass
class PlacementDecision:
    target: str                      # "local" | "remote"
    reason: str
    est_local_s: float = 0.0
    est_remote_s: float = 0.0
    migration_s: float = 0.0
    speedup: float = 1.0


SENSITIVITY_RANK = {"public": 0, "personal": 1, "confidential": 2}


def placement_allowed(sensitivity: str, profile: DeviceProfile,
                      max_unattested: str = "public") -> bool:
    """The sensitivity/attestation rule, factored out so the fleet router
    and the pairwise daemon share one policy: data above
    ``max_unattested`` may only be placed on an attested device."""
    if profile.attested:
        return True
    return SENSITIVITY_RANK[sensitivity] <= SENSITIVITY_RANK[max_unattested]


class PrivacyAwareDaemon:
    def __init__(self, local: DeviceProfile = EDGE,
                 remote: DeviceProfile = CLOUD,
                 net: NetworkCondition | None = None,
                 *, min_speedup: float = 1.5,
                 amortize_factor: float = 2.0,
                 max_remote_sensitivity: str = "personal"):
        self.local, self.remote = local, remote
        self.net = net or NetworkCondition()
        self.min_speedup = min_speedup
        self.amortize_factor = amortize_factor
        self.max_remote_sensitivity = max_remote_sensitivity

    # -- roofline cost model -------------------------------------------------
    @staticmethod
    def step_time(cfg: ModelConfig, profile: DeviceProfile, *,
                  prefill_tokens: int = 0, decode_tokens: int = 0,
                  param_bytes: int | None = None) -> float:
        from repro.models.init import param_bytes as pb
        n_bytes = param_bytes if param_bytes is not None else pb(cfg)
        active_bytes = n_bytes
        if cfg.moe is not None:          # only routed top-k touched/token
            m = cfg.moe
            frac = (m.top_k + m.num_shared) / (m.num_experts + m.num_shared)
            active_bytes = int(n_bytes * max(frac, 0.05))
        n_active_params = active_bytes // 2          # bf16
        t = 0.0
        if prefill_tokens:                           # MXU-bound
            t += 2 * n_active_params * prefill_tokens / profile.agg_flops
        if decode_tokens:                            # HBM-bound
            t += decode_tokens * active_bytes / profile.agg_bw
        return t

    def migration_time(self, workspace_bytes: int,
                       compress_ratio: float = 4.0) -> float:
        wire = workspace_bytes / compress_ratio
        return (self.net.transfer_time(int(wire))
                + 0.05          # attestation (paper: ~50ms)
                + workspace_bytes / 2e9 * 2)  # serialize+restore @2GB/s

    # -- decision -------------------------------------------------------------
    def decide(self, *, sensitivity: str, cfg: ModelConfig,
               prefill_tokens: int, decode_tokens: int,
               workspace_bytes: int,
               param_bytes: int | None = None) -> PlacementDecision:
        if SENSITIVITY_RANK[sensitivity] > \
                SENSITIVITY_RANK[self.max_remote_sensitivity]:
            return PlacementDecision("local",
                                     f"policy: {sensitivity} data must "
                                     "stay in the local enclave")
        if not self.remote.attested:
            return PlacementDecision("local", "remote enclave unattested")
        if not self.net.up:
            return PlacementDecision("local", "network down")

        t_local = self.step_time(cfg, self.local,
                                 prefill_tokens=prefill_tokens,
                                 decode_tokens=decode_tokens,
                                 param_bytes=param_bytes)
        t_remote = self.step_time(cfg, self.remote,
                                  prefill_tokens=prefill_tokens,
                                  decode_tokens=decode_tokens,
                                  param_bytes=param_bytes)
        t_mig = self.migration_time(workspace_bytes)
        speedup = t_local / max(t_remote, 1e-12)
        dec = PlacementDecision("local", "", t_local, t_remote, t_mig,
                                speedup)
        if speedup < self.min_speedup:
            dec.reason = (f"speedup {speedup:.2f}x < "
                          f"{self.min_speedup}x threshold")
            return dec
        if t_local < self.amortize_factor * t_mig:
            dec.reason = (f"work {t_local:.2f}s < {self.amortize_factor}x "
                          f"migration {t_mig:.2f}s (not amortized)")
            return dec
        dec.target = "remote"
        dec.reason = (f"speedup {speedup:.2f}x, work {t_local:.2f}s >= "
                      f"{self.amortize_factor}x migration {t_mig:.2f}s")
        return dec
