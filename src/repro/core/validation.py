"""Continuous validation framework (paper §3.5, §9.8, Table 3).

The paper's validators are themselves models (medical validity checkers,
content filters).  We reproduce the *framework* faithfully -- validators
that run in parallel with generation, can intervene mid-stream, and whose
overhead is accounted parallel-vs-serial -- over a synthetic token
semantics (documented, since the substrate is tokenizer-free):

  token id ranges carry meaning in the synthetic language:
    [10, 20)  harmful-content markers
    [20, 30)  PII / privacy-leak markers
    [30, 40)  medical-error markers
    [40, 50)  compliance-violation markers
  hallucination is *statistical*: a low average token log-probability /
  high entropy stretch (the standard confidence-based detector).

Detection/false-positive rates (Table 3) are measured by the benchmark
against planted labels; rates land near the paper's because detector
thresholds trade off exactly like the originals.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

HARMFUL = range(10, 20)
PII = range(20, 30)
MEDICAL = range(30, 40)
COMPLIANCE = range(40, 50)


@dataclass
class Verdict:
    ok: bool
    kind: str
    confidence: float
    position: int = -1


class Validator:
    name = "base"
    kind = "generic"

    def check(self, tokens: list[int],
              logprobs: Optional[list[float]] = None) -> Verdict:
        raise NotImplementedError


class MarkerValidator(Validator):
    """Range-marker detector with a miss/false-positive noise floor so
    detection curves behave like model-based checkers."""

    def __init__(self, name, kind, token_range, miss_rate=0.0,
                 fp_rate=0.0, seed=0):
        self.name, self.kind = name, kind
        self.range = token_range
        self.miss_rate, self.fp_rate = miss_rate, fp_rate
        self.rng = np.random.default_rng(seed)

    def check(self, tokens, logprobs=None) -> Verdict:
        for i, t in enumerate(tokens):
            if t in self.range:
                if self.rng.random() < self.miss_rate:
                    continue  # detector miss
                return Verdict(False, self.kind, 0.99, i)
        if self.rng.random() < self.fp_rate:
            return Verdict(False, self.kind, 0.55, -1)
        return Verdict(True, self.kind, 0.99)


class HallucinationValidator(Validator):
    """Confidence-based: flags stretches of low token log-probability."""
    name, kind = "hallucination", "hallucination"

    def __init__(self, threshold: float = -4.0, window: int = 4,
                 miss_rate: float = 0.05, seed: int = 1):
        self.threshold, self.window = threshold, window
        self.miss_rate = miss_rate
        self.rng = np.random.default_rng(seed)

    def check(self, tokens, logprobs=None) -> Verdict:
        if not logprobs or len(logprobs) < self.window:
            return Verdict(True, self.kind, 0.5)
        lp = np.asarray(logprobs)
        roll = np.convolve(lp, np.ones(self.window) / self.window,
                           mode="valid")
        i = int(np.argmin(roll))
        if roll[i] < self.threshold and self.rng.random() > self.miss_rate:
            return Verdict(False, self.kind, float(-roll[i] / 10), i)
        return Verdict(True, self.kind, 0.9)


def default_zoo(seed: int = 0) -> list[Validator]:
    """Table-3 validator set with noise floors tuned to the paper's
    detection / false-positive operating points."""
    return [
        HallucinationValidator(miss_rate=0.058, seed=seed + 1),
        MarkerValidator("harmful_content", "harmful", HARMFUL,
                        miss_rate=0.003, fp_rate=0.003, seed=seed + 2),
        MarkerValidator("privacy_leak", "privacy", PII,
                        miss_rate=0.032, fp_rate=0.012, seed=seed + 3),
        MarkerValidator("medical_error", "medical", MEDICAL,
                        miss_rate=0.029, fp_rate=0.018, seed=seed + 4),
        MarkerValidator("financial_compliance", "compliance", COMPLIANCE,
                        miss_rate=0.011, fp_rate=0.007, seed=seed + 5),
    ]


@dataclass
class ValidationReport:
    verdicts: list
    intervened: bool
    halt_position: int
    wall_s: float
    mode: str


class ValidationFramework:
    """Parallel-with-generation vs serial post-hoc validation.

    Parallel mode checks the emitted stream every ``stride`` tokens
    *while decoding continues* and can halt a request mid-generation
    (paper: "intervene during execution, preventing harmful outputs from
    reaching users"); serial mode validates only after generation ends.
    """

    def __init__(self, validators: Optional[list] = None,
                 stride: int = 4):
        self.validators = validators or default_zoo()
        self.stride = stride

    def validate_stream(self, emit_fn: Callable[[], Optional[int]],
                        logprob_fn=None) -> tuple[list[int], ValidationReport]:
        """Parallel mode: pull tokens from ``emit_fn`` (None = done),
        validating every stride; halt on intervention."""
        t0 = time.perf_counter()
        tokens: list[int] = []
        logprobs: list[float] = []
        verdicts = []
        while True:
            t = emit_fn()
            if t is None:
                break
            tokens.append(t)
            if logprob_fn is not None:
                logprobs.append(logprob_fn())
            if len(tokens) % self.stride == 0:
                for v in self.validators:
                    vd = v.check(tokens, logprobs or None)
                    if not vd.ok:
                        verdicts.append(vd)
                        return tokens[:max(vd.position, 0)], \
                            ValidationReport(verdicts, True,
                                             vd.position,
                                             time.perf_counter() - t0,
                                             "parallel")
        verdicts = [v.check(tokens, logprobs or None)
                    for v in self.validators]
        bad = [v for v in verdicts if not v.ok]
        return tokens, ValidationReport(
            verdicts, bool(bad), bad[0].position if bad else -1,
            time.perf_counter() - t0, "parallel")

    def validate_post_hoc(self, tokens: list[int],
                          logprobs=None) -> ValidationReport:
        """Serial mode: everything already reached the user."""
        t0 = time.perf_counter()
        verdicts = [v.check(tokens, logprobs) for v in self.validators]
        bad = [v for v in verdicts if not v.ok]
        return ValidationReport(verdicts, bool(bad),
                                bad[0].position if bad else -1,
                                time.perf_counter() - t0, "serial")
