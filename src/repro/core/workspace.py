"""AgentWorkspace: the migratable unit of MVVM (paper §2.1).

Everything an agent needs to resume exactly where it stopped:
  * engine_state  -- KV caches / SSM states, generated tokens, per-slot
                     positions, sampling RNG keys, step counter
                     (serving.EngineState; the "WASM locals + stack")
  * requests      -- in-flight request metadata (the "tool state")
  * measurement   -- config + weight Merkle root (binds state to model)
  * vclock        -- vector clock for replica synchronization
  * phase/step    -- the stable-point instruction pointer analogue
"""

from __future__ import annotations

from dataclasses import dataclass, field


from repro.serving.engine import (Engine, EngineState,
                                  request_from_dict, request_to_dict)


@dataclass
class VectorClock:
    clocks: dict[str, int] = field(default_factory=dict)

    def tick(self, node: str) -> "VectorClock":
        c = dict(self.clocks)
        c[node] = c.get(node, 0) + 1
        return VectorClock(c)

    def merge(self, other: "VectorClock") -> "VectorClock":
        keys = set(self.clocks) | set(other.clocks)
        return VectorClock({k: max(self.clocks.get(k, 0),
                                   other.clocks.get(k, 0)) for k in keys})

    def dominates(self, other: "VectorClock") -> bool:
        keys = set(self.clocks) | set(other.clocks)
        return all(self.clocks.get(k, 0) >= other.clocks.get(k, 0)
                   for k in keys)

    def concurrent(self, other: "VectorClock") -> bool:
        return not self.dominates(other) and not other.dominates(self)


@dataclass
class AgentWorkspace:
    engine_state: EngineState
    requests: list[dict]
    config_name: str
    measurement: str                  # global_id binding state to model
    phase: str = "decode"             # stable-point phase
    step: int = 0                     # stable-point index within phase
    vclock: VectorClock = field(default_factory=VectorClock)

    @classmethod
    def from_engine(cls, engine: Engine, measurement: str,
                    node: str = "src") -> "AgentWorkspace":
        reqs = [request_to_dict(r) for r in engine.requests.values()]
        return cls(engine_state=engine.state, requests=reqs,
                   config_name=engine.cfg.name, measurement=measurement,
                   step=int(engine.state.step_count),
                   vclock=VectorClock().tick(node))

    def attach(self, engine: Engine) -> Engine:
        """Install this workspace into a compatible engine (restore)."""
        assert engine.cfg.name.split("-tiny")[0] == \
            self.config_name.split("-tiny")[0], "config mismatch"
        engine.state = self.engine_state
        engine.requests = {}
        for r in self.requests:
            req = request_from_dict(r)
            if not req.done:
                engine.requests[req.slot] = req
        return engine
