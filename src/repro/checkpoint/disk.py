"""Distributed disk checkpointing with restart + elastic resharding.

Layout (tensorstore-style, stdlib-only):
    <dir>/step_<n>/
        MANIFEST.json      {step, leaf paths, shapes, dtypes, tree def}
        <leaf_id>.npy      one file per pytree leaf

Restore is *mesh-agnostic*: arrays are loaded on host then device_put
against the target sharding -- restoring a 16x16-trained checkpoint onto
a 2x16x16 mesh (elastic scale-up) or a 1-chip debug mesh is the same
code path the MVVM migration layer uses (core/migration.py reuses
``serialize_tree``/``deserialize_tree``)."""

from __future__ import annotations

import json
import os
import re
import shutil

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keyed = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        keyed[key] = leaf
    return keyed, treedef


def save(directory: str, step: int, tree, *, keep: int = 3) -> str:
    """Atomic checkpoint write (tmp dir + rename)."""
    keyed, _ = _flatten(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": {}}
    for i, (key, leaf) in enumerate(sorted(keyed.items())):
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(arr.dtype)
        if dtype == "bfloat16":  # numpy can't serialize ml_dtypes natively
            arr = arr.view(np.uint16)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": dtype}
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore(directory: str, step: int, like_tree, *, shardings=None):
    """Restore into the structure of ``like_tree`` (abstract ok).

    ``shardings``: optional matching pytree of NamedSharding -- enables
    restore-onto-a-different-mesh (elastic restart)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)
    keyed_like, treedef = _flatten(like_tree)
    flat_shard = None
    if shardings is not None:
        keyed_shard, _ = _flatten(shardings)
        flat_shard = keyed_shard
    out = {}
    for key in keyed_like:
        meta = manifest["leaves"][key]
        arr = np.load(os.path.join(path, meta["file"]))
        if meta["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        if flat_shard is not None:
            arr = jax.device_put(arr, flat_shard[key])
        else:
            arr = jnp.asarray(arr)
        out[key] = arr
    ordered = [out[k] for k in keyed_like]  # keyed_like preserves tree order
    return jax.tree.unflatten(treedef, ordered)


def _gc(directory: str, keep: int):
    steps = sorted(
        int(m.group(1)) for d in os.listdir(directory)
        if (m := re.fullmatch(r"step_(\d+)", d)))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)
