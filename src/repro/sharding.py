"""Logical-axis sharding rules (MaxText-style) with auto-degradation.

Every parameter / activation dimension is named with a *logical* axis
("batch", "heads", "mlp", ...).  A rule table maps logical axes to mesh
axes.  ``resolve`` turns a tuple of logical names into a
``PartitionSpec`` for a concrete mesh, dropping any rule whose mesh axes
do not divide the dimension (auto-degradation to replication).  This is
what lets one model definition lower onto the 1-device CPU mesh, the
16x16 single-pod mesh and the 2x16x16 multi-pod mesh without per-mesh
hand edits: e.g. gemma3's 8 query heads cannot shard over a 16-way
"model" axis, so "heads" degrades to replicated while "mlp" stays TP.
"""

from __future__ import annotations

import math
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default logical->mesh rules.  Values are a mesh-axis name, a tuple of
# mesh-axis names, or None (replicate).
DEFAULT_RULES: dict[str, tuple[str, ...] | str | None] = {
    # activations
    "batch": ("pod", "data"),          # data parallel over pod x data
    "seq": None,                       # sequence replicated by default
    "seq_shard": ("data",),            # opt-in sequence parallelism (long ctx)
    "embed": None,
    "act_heads": "model",
    "act_kv_heads": "model",
    # parameters
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    # KV-cache head_dim: falls back to "model" when kv_heads don't divide
    # the model axis (GQA kv < 16) -- contracting-dim TP for decode, keeps
    # 32k x batch caches on-chip (resolve()'s used-set makes this a no-op
    # when kv_heads already took the axis)
    "kv_dim": "model",
    "cache_seq": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_ff": None,
    "inner": "model",                  # mamba d_inner / rwkv fused head dim
    "state": None,
    "conv": None,
    "lora": None,
    "stack": None,                     # scan-stacked leading layer dim
}


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    """jax.shard_map across jax versions: new jax has it at top level
    with ``check_vma``; older jax spells it jax.experimental.shard_map
    with ``check_rep``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def _axis_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def resolve(
    logical: Sequence[str | None],
    mesh: Mesh,
    dims: Sequence[int] | None = None,
    overrides: Mapping[str, tuple[str, ...] | str | None] | None = None,
) -> P:
    """Resolve logical axis names to a PartitionSpec for ``mesh``.

    ``dims`` (optional) enables divisibility-based auto-degradation:
    a rule is dropped when the dimension is not divisible by the mesh
    axes' product.  Mesh axes absent from ``mesh`` are dropped, and a
    mesh axis is never used twice in one spec.
    """
    rules = dict(DEFAULT_RULES)
    if overrides:
        rules.update(overrides)
    out: list[tuple[str, ...] | None] = []
    used: set[str] = set()
    for i, name in enumerate(logical):
        if name is None:
            out.append(None)
            continue
        rule = rules.get(name)
        if rule is None:
            out.append(None)
            continue
        axes = (rule,) if isinstance(rule, str) else tuple(rule)
        axes = tuple(a for a in axes if a in mesh.shape and a not in used)
        if not axes:
            out.append(None)
            continue
        if dims is not None:
            dim = dims[i]
            if dim % _axis_size(mesh, axes) != 0:
                # try progressively shorter prefixes before replicating
                while axes and dim % _axis_size(mesh, axes) != 0:
                    axes = axes[:-1]
                if not axes:
                    out.append(None)
                    continue
        used.update(axes)
        out.append(axes if len(axes) > 1 else axes[0])
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def named_sharding(
    mesh: Mesh,
    logical: Sequence[str | None],
    dims: Sequence[int] | None = None,
    overrides=None,
) -> NamedSharding:
    return NamedSharding(mesh, resolve(logical, mesh, dims, overrides))


def tree_specs(schema_tree, mesh: Mesh, overrides=None):
    """Map a pytree of ``ParamDef`` (see models.schema) to PartitionSpecs."""
    from repro.models.schema import ParamDef  # local import to avoid cycle

    def leaf(pd):
        if isinstance(pd, ParamDef):
            return resolve(pd.logical, mesh, pd.shape, overrides)
        return P()

    return jax.tree.map(leaf, schema_tree,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def constrain(x, mesh: Mesh, logical: Sequence[str | None], overrides=None):
    """with_sharding_constraint via logical names (no-op off-mesh)."""
    if mesh is None or mesh.empty:
        return x
    spec = resolve(logical, mesh, x.shape, overrides)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
