"""Fleet serving driver: N heterogeneous engines behind one queue.

    PYTHONPATH=src python -m repro.launch.fleet \
        --arch llama-1.5b --tiny --requests 12 --max-new 16 \
        --engines edge:edge,cloud:cloud,mcu:mcu --fail cloud@5

Quality tiers (cross-model fleet with graceful degradation): a full
bf16 tier next to an int8 tier; saturate the full tier or cut its link
and watch requests downshift (typed QualityEvents), never below their
quality floor:

    PYTHONPATH=src python -m repro.launch.fleet --tiny --requests 8 \
        --tiers full:1.0:bf16,lite:0.6:int8 \
        --engines big:cloud:128:full,small:edge:128:lite \
        --slots 2 --quality-floor 0,0.8

(add --link-down big@4 to cut the full tier's client link mid-run:
floor-0 traffic downshifts to the lite tier, floored work waits)

Speculative tier hand-off (draft on edge, verify on cloud):

    PYTHONPATH=src python -m repro.launch.fleet --tiny --requests 8 \
        --engines edge:edge:96,cloud:cloud:256,mcu:mcu \
        --spec-tiers edge:cloud --drafter-temperature 0.8

Priorities + preemption-by-migration (lifecycle API): one engine, a
mixed-priority stream -- watch low-priority slots get parked
(extract_slot/pack_slot) and resume when the high-priority work clears:

    PYTHONPATH=src python -m repro.launch.fleet --tiny --requests 6 \
        --engines edge:edge --slots 2 --priorities 0,5,10 \
        --queue-limit 1 --deadline-s 60

Elastic autoscaling: a one-engine fleet grows under the burst (watch
the spawn ScaleEvents), then drains the spawned engines -- every live
slot migrating or parking via the migration path -- once idle:

    PYTHONPATH=src python -m repro.launch.fleet --tiny --requests 12 \
        --engines edge:edge --slots 2 --autoscale 1:3 \
        --scale-up-queue-depth 3 --scale-cooldown-s 0

Service mode (the control-plane/engine-service split): each engine
decodes on its own thread behind a mailbox, messages ride loopback TCP
(length-prefixed msgpack frames), and engines decode *concurrently* --
jitted steps release the GIL:

    PYTHONPATH=src python -m repro.launch.fleet --tiny --requests 12 \
        --engines a:edge,b:edge,c:edge --transport socket

Flags
  --arch NAME            model config (default llama-1.5b)
  --tiny                 shrink the config (CPU-friendly smoke scale)
  --engines SPEC         comma list of name:profile[:max_len][:tier]
                         replicas, where profile is edge | cloud | mcu
                         (mcu is the unattested endpoint -- the router
                         will keep personal/confidential work off it);
                         max_len overrides --max-len per engine
                         (heterogeneous context budgets migrate via
                         repack_slot); tier names a --tiers entry
  --tiers SPEC           comma list of name:quality[:kind] quality
                         tiers (kind: bf16 = fleet weights, int8 =
                         quantize/dequantize the fleet weights, small =
                         a narrower model with fresh weights).  Engines
                         of different tiers run distinct weights, so
                         cross-tier moves re-prefill the committed
                         stream (lossy) instead of shipping cache rows
  --quality-floor LIST   comma list of floors in [0,1] cycled across
                         the synthetic requests: a request is never
                         served below its floor (it queues instead)
  --link-down NAME@STEP  cut engine NAME's client link at fleet step
                         STEP: the router degrades its traffic to
                         reachable tiers (QualityEvents on the log)
  --slots N              request slots per engine (default 4)
  --max-len N            per-slot context budget (default 128)
  --requests N           synthetic mixed-sensitivity request count
  --max-new N            tokens generated per request (default 16)
  --temperature F        sampling temperature for odd-numbered requests
                         (even ones stay greedy: mixed-policy batches)
  --priorities LIST      comma list of ints cycled across the synthetic
                         requests (e.g. 0,5,10); a higher-priority
                         arrival preempts the lowest-priority in-flight
                         slot via the migration machinery when no slot
                         is free
  --deadline-s F         relative deadline per request (seconds on the
                         fleet clock); queued or parked work past it
                         expires instead of occupying capacity
  --queue-limit N        admission-control bound (backpressure beyond it)
  --autoscale MIN:MAX    arm the autoscaler: keep between MIN and MAX
                         routable engines, spawning from a template
                         (profile --autoscale-profile, geometry
                         --slots/--max-len) under queue/deadline
                         pressure and retiring spawned engines once
                         idle -- scale-down drains every slot via the
                         migration path before the handle disappears
  --autoscale-profile P  device profile for spawned engines (default
                         edge; attested, so spawned capacity can take
                         sensitive work)
  --scale-up-queue-depth N  pending work (fresh + parked) that triggers
                         a spawn (default 4; 0 disables the signal)
  --scale-up-wait-p95 F  recent queue-wait p95 (seconds) that triggers
                         a spawn (default: off)
  --scale-cooldown-s F   minimum fleet-clock seconds between scale
                         events (default 0)
  --aging-rate F         priority points gained per second of queue
                         wait, so starved low-priority work eventually
                         dispatches (default 0 = strict priority)
  --transport MODE       sim (default): the synchronous fleet loop on
                         the deterministic in-process fabric -- every
                         contract (bit-exactness, conservation, spec
                         pairs, autoscaling, preemption) holds here.
                         socket: service mode -- a ControlPlane plus
                         one EngineService thread per engine, messages
                         over loopback TCP; requests stream
                         concurrently and failures are detected by
                         heartbeat.  Step-indexed chaos flags (--fail /
                         --drain / --link-down), --spec-tiers and
                         --autoscale are sim-only
  --sync-every N         shadow-checkpoint cadence in fleet steps
  --rebalance-every N    load-smoothing cadence (0 = off, default)
  --fail NAME@STEP       fail-stop engine NAME before fleet step STEP;
                         its in-flight requests are re-placed from
                         shadow checkpoints and resume on survivors
  --drain NAME@STEP      live-migrate everything off NAME at step STEP
  --spec-tiers SPEC      comma list of draft:verify engine pairs; each
                         pair drafts greedily-served requests on the
                         draft engine and teacher-force verifies them on
                         the verify engine via a one-time slot hand-off
  --spec-gamma N         draft tokens per verify round (default 4)
  --drafter-temperature F  draft-tier sampling temperature (committed
                         output stays the target's greedy choice)
  --drafter-top-k N      draft-tier top-k (default 0 = full vocab)
  --verify-mode MODE     stepwise (bit-exact, default) | wide (one
                         multi-query pass) | distribution (standard
                         speculative-sampling accept/reject: required
                         when draft and verify engines are different
                         quality tiers; see fleet.speculative docs)
  --seed N               rng seed for prompts and engines
"""

from __future__ import annotations

import argparse
import dataclasses
import json

PROFILES = {"edge": "EDGE", "cloud": "CLOUD", "mcu": "MCU"}


def parse_event(spec: str | None) -> tuple[str, int] | None:
    if not spec:
        return None
    name, step = spec.rsplit("@", 1)
    return name, int(step)


def parse_tiers(spec: str | None) -> dict[str, str]:
    if not spec:
        return {}
    pairs = {}
    for item in spec.split(","):
        draft, _, verify = item.partition(":")
        pairs[draft] = verify
    return pairs


def parse_quality_tiers(spec: str | None):
    """--tiers full:1.0:bf16,lite:0.6:int8 -> {name: QualityTier}."""
    from repro.core.replication import QualityTier
    tiers = {}
    for item in (spec or "").split(","):
        if not item:
            continue
        parts = item.split(":")
        name = parts[0]
        quality = float(parts[1]) if len(parts) > 1 else 1.0
        kind = parts[2] if len(parts) > 2 else "bf16"
        assert kind in ("bf16", "int8", "small"), kind
        tiers[name] = QualityTier(name, quality, kind)
    return tiers


def tier_model(cfg, params, tier, seed: int):
    """The (cfg, params) a tier's engines run: the fleet weights
    (bf16), an int8 round-trip of them, or a narrower model with its
    own fresh weights (same tokenizer)."""
    import jax
    import jax.numpy as jnp
    from repro.models.init import init_params
    from repro.optim.compression import dequantize_int8, quantize_int8
    if tier.kind == "int8":
        def f(w):
            if hasattr(w, "dtype") and jnp.issubdtype(w.dtype,
                                                      jnp.floating):
                q, s = quantize_int8(w)
                return dequantize_int8(q, s).astype(w.dtype)
            return w
        return cfg, jax.tree.map(f, params)
    if tier.kind == "small":
        small = cfg.replace(name=cfg.name + f"-{tier.name}",
                            blocks=cfg.blocks[:max(len(cfg.blocks) // 2,
                                                   1)])
        return small, init_params(small, jax.random.key(seed))
    return cfg, params


def main():
    ap = argparse.ArgumentParser(
        description="serve a request stream over a heterogeneous fleet")
    ap.add_argument("--arch", default="llama-1.5b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--engines", default="edge:edge,cloud:cloud,mcu:mcu")
    ap.add_argument("--tiers", default=None, metavar="NAME:Q[:KIND]")
    ap.add_argument("--quality-floor", default="0", metavar="LIST")
    ap.add_argument("--link-down", default=None, metavar="NAME@STEP")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--priorities", default="0", metavar="LIST")
    ap.add_argument("--deadline-s", type=float, default=None)
    ap.add_argument("--queue-limit", type=int, default=32)
    ap.add_argument("--autoscale", default=None, metavar="MIN:MAX")
    ap.add_argument("--autoscale-profile", default="edge",
                    choices=sorted(PROFILES))
    ap.add_argument("--scale-up-queue-depth", type=int, default=4)
    ap.add_argument("--scale-up-wait-p95", type=float, default=None)
    ap.add_argument("--scale-cooldown-s", type=float, default=0.0)
    ap.add_argument("--warm-pool", type=int, default=0, metavar="N",
                    help="hold N pre-built, attested, program-warmed "
                         "standby engines outside the routable set; "
                         "scale-up promotes one in milliseconds instead "
                         "of constructing inline (needs --autoscale)")
    ap.add_argument("--prearm-horizon", type=float, default=0.0,
                    metavar="SECONDS",
                    help="fill the warm pool only when the queue-trend "
                         "forecast projects the scale-up depth trigger "
                         "within this horizon (0 = keep it topped up)")
    ap.add_argument("--aging-rate", type=float, default=0.0)
    ap.add_argument("--transport", default="sim",
                    choices=["sim", "socket"])
    ap.add_argument("--sync-every", type=int, default=1)
    ap.add_argument("--rebalance-every", type=int, default=0)
    ap.add_argument("--fail", default=None, metavar="NAME@STEP")
    ap.add_argument("--drain", default=None, metavar="NAME@STEP")
    ap.add_argument("--spec-tiers", default=None, metavar="DRAFT:VERIFY")
    ap.add_argument("--spec-gamma", type=int, default=4)
    ap.add_argument("--drafter-temperature", type=float, default=0.0)
    ap.add_argument("--drafter-top-k", type=int, default=0)
    ap.add_argument("--verify-mode", default="stepwise",
                    choices=["stepwise", "wide", "distribution"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefix-cache", action="store_true",
                    help="spawn paged engines with the content-"
                         "addressed prefix cache armed (shared KV "
                         "pages, COW forks, session-affine routing)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV page size for --prefix-cache engines")
    ap.add_argument("--tenants", type=int, default=0, metavar="N",
                    help="cycle requests over N tenants, each reusing "
                         "its own system-prompt prefix (exercises "
                         "warm-session routing; needs --prefix-cache)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the run's Chrome trace-event JSON here "
                         "(open in Perfetto / chrome://tracing)")
    ap.add_argument("--otlp-out", default=None, metavar="PATH",
                    help="write the run's spans as an OTLP-JSON "
                         "ExportTraceServiceRequest here (feed to any "
                         "OpenTelemetry collector/backend)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a Prometheus text exposition of the "
                         "fleet metrics registry here")
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get
    from repro.configs.tiny import make_tiny
    from repro.core import daemon
    from repro.core.attestation import TrustAuthority
    from repro.fleet import (Autoscaler, EngineHandle, EngineTemplate,
                             FleetController, Rebalancer, RequestSpec,
                             ScalePolicy)
    from repro.models.init import init_params
    from repro.serving.engine import Engine
    from repro.serving.paged import PagedEngine

    cfg = get(args.arch)
    if args.tiny:
        cfg = make_tiny(cfg)
    params = init_params(cfg, jax.random.key(args.seed))

    quality_tiers = parse_quality_tiers(args.tiers)
    tier_models = {}                  # tier name -> (cfg, params)
    for i, (tname, qt) in enumerate(quality_tiers.items()):
        tier_models[tname] = tier_model(cfg, params, qt,
                                        args.seed + 1000 + i)

    handles = []
    for i, spec in enumerate(args.engines.split(",")):
        parts = spec.split(":")
        name, prof = parts[0], parts[1] if len(parts) > 1 else ""
        if prof not in PROFILES:
            ap.error(f"unknown profile {prof!r} in --engines {spec!r} "
                     f"(choose from {sorted(PROFILES)})")
        profile = getattr(daemon, PROFILES[prof])
        max_len = int(parts[2]) if len(parts) > 2 and parts[2] \
            else args.max_len
        kw = {}
        ecfg, eparams = cfg, params
        if len(parts) > 3:
            if parts[3] not in quality_tiers:
                ap.error(f"--engines {spec!r} names tier {parts[3]!r} "
                         f"missing from --tiers")
            kw["tier"] = quality_tiers[parts[3]]
            ecfg, eparams = tier_models[parts[3]]
        if args.prefix_cache:
            if max_len % args.page_size:
                ap.error(f"--engines {spec!r}: max_len {max_len} not a "
                         f"multiple of --page-size {args.page_size}")
            eng = PagedEngine(ecfg, eparams, rows=args.slots,
                              page_size=args.page_size, max_len=max_len,
                              seed=args.seed + i, prefix_cache=True)
        else:
            eng = Engine(ecfg, eparams, slots=args.slots, max_len=max_len,
                         seed=args.seed + i)
        handles.append(EngineHandle(name, eng, profile, **kw))
    spec_tiers = parse_tiers(args.spec_tiers)
    for dname, vname in spec_tiers.items():
        if dname not in {h.name for h in handles} or \
                vname not in {h.name for h in handles}:
            ap.error(f"--spec-tiers pair {dname}:{vname} names an "
                     "engine missing from --engines")
    autoscaler = None
    if args.autoscale:
        lo, _, hi = args.autoscale.partition(":")
        autoscaler = Autoscaler(
            EngineTemplate(name="auto",
                           profile=getattr(
                               daemon, PROFILES[args.autoscale_profile]),
                           slots=args.slots, max_len=args.max_len,
                           seed=args.seed + 100,
                           page_size=args.page_size
                           if args.prefix_cache else 0,
                           prefix_cache=args.prefix_cache),
            ScalePolicy(min_engines=int(lo), max_engines=int(hi or lo),
                        scale_up_queue_depth=args.scale_up_queue_depth,
                        scale_up_wait_p95=args.scale_up_wait_p95,
                        cooldown_s=args.scale_cooldown_s,
                        standby_pool=args.warm_pool,
                        prearm_horizon_s=args.prearm_horizon))
    fleet = FleetController(
        handles, authority=TrustAuthority(),
        balancer=Rebalancer(sync_every=args.sync_every),
        queue_limit=args.queue_limit,
        rebalance_every=args.rebalance_every,
        autoscaler=autoscaler,
        aging_rate=args.aging_rate,
        spec_tiers=spec_tiers,
        spec_options={"gamma": args.spec_gamma,
                      "drafter_temperature": args.drafter_temperature,
                      "drafter_top_k": args.drafter_top_k,
                      "verify_mode": args.verify_mode})

    rng = np.random.default_rng(args.seed)
    sens = ["public", "personal", "confidential"]
    prios = [int(p) for p in args.priorities.split(",")]
    floors = [float(f) for f in args.quality_floor.split(",")]
    # multi-tenant traffic: each tenant reuses its own "system prompt"
    # (2 pages of tokens) ahead of a per-request tail, so later requests
    # of a tenant hit the prefix pages its first request cached
    bases = {}
    if args.tenants:
        for t in range(args.tenants):
            bases[f"t{t}"] = rng.integers(5, cfg.vocab_size,
                                          2 * args.page_size)
    pending = []
    for i in range(args.requests):
        tenant = f"t{i % args.tenants}" if args.tenants else ""
        tail = rng.integers(5, cfg.vocab_size, 8)
        prompt = np.concatenate([bases[tenant], tail]) if tenant else tail
        pending.append(
            RequestSpec(rid=f"r{i}", prompt=prompt,
                        max_new_tokens=args.max_new,
                        temperature=args.temperature if i % 2 else 0.0,
                        top_k=16 if i % 2 else 0,
                        sensitivity=sens[i % 3],
                        priority=prios[i % len(prios)],
                        quality_floor=floors[i % len(floors)],
                        tenant=tenant))

    if args.transport == "socket":
        if spec_tiers or autoscaler is not None or args.fail \
                or args.drain or args.link_down:
            ap.error("--transport socket serves plain engines only: "
                     "--spec-tiers/--autoscale and the step-indexed "
                     "chaos flags (--fail/--drain/--link-down) are "
                     "sim-only (see the README transport matrix)")
        from repro.core.channel import SocketTransport
        from repro.fleet import ControlPlane
        cp = ControlPlane(fleet, transport=SocketTransport(),
                          sync_every=max(args.sync_every, 1))
        cp.start(threads=True)
        import time
        t0 = time.perf_counter()
        cp.serve(pending, timeout_s=600.0)
        wall = time.perf_counter() - t0
        cp.stop()
        for rid in sorted(fleet.tickets):
            t = fleet.tickets[rid]
            route = "->".join(fleet.placements.get(rid, [])) or "-"
            out = t.output
            print(f"{rid}[{t.spec.sensitivity:12s} p{t.spec.priority:<3d} "
                  f"{t.state.value:9s}] via {route}: "
                  f"{out[:8]}{'...' if len(out) > 8 else ''}")
        summ = fleet.telemetry.summary()
        toks = sum(len(t.output) for t in fleet.tickets.values())
        print(json.dumps(summ, indent=1))
        print(f"service mode: {len(fleet.tickets)} requests, "
              f"{toks} tokens in {wall:.2f}s wall "
              f"({toks / max(wall, 1e-9):.1f} tok/s aggregate, "
              f"{fleet.telemetry.heartbeat_losses} heartbeat losses)")
        if args.trace_out and fleet.tracer is not None:
            fleet.tracer.close_open(reason="run complete")
            fleet.tracer.export_chrome(args.trace_out)
            print(f"trace: {args.trace_out}")
        if args.metrics_out:
            with open(args.metrics_out, "w") as f:
                f.write(fleet.telemetry.prometheus_text())
            print(f"metrics: {args.metrics_out}")
        return

    fail = parse_event(args.fail)
    drain = parse_event(args.drain)
    link_down = parse_event(args.link_down)
    tickets = {}
    step = 0
    while pending or fleet.queue or fleet.orphans or fleet.inflight:
        while pending:
            spec = pending[0]
            if args.deadline_s is not None:
                # relative per request: anchor at actual submission,
                # not at driver startup (backpressure must not shrink
                # the window)
                spec = dataclasses.replace(
                    spec, deadline=fleet.clock() + args.deadline_s)
            t = fleet.submit(spec)
            if t is None:
                break                # queue full: back off a step
            tickets[t.rid] = t
            pending.pop(0)
        if fail and step == fail[1]:
            print(f"-- failing {fail[0]} at step {step} --")
            fleet.fail(fail[0])
        if drain and step == drain[1]:
            print(f"-- draining {drain[0]} at step {step} --")
            fleet.drain(drain[0])
        if link_down and step == link_down[1]:
            from repro.core.channel import NetworkCondition
            print(f"-- link to {link_down[0]} down at step {step} --")
            fleet.set_link(link_down[0], NetworkCondition(up=False))
        qlen, orph = len(fleet.queue), len(fleet.orphans)
        fleet.step()
        step += 1
        if fleet.is_stalled(qlen, orph):
            fleet._dispatch()        # slots may have freed this step
            if not fleet.is_stalled(qlen, orph):
                continue
            # stalled: backlog no surviving engine is eligible to take
            for req, _ in fleet.queue:
                dec = fleet.router.route(
                    list(fleet.handles.values()), cfg,
                    sensitivity=req.sensitivity,
                    prefill_tokens=len(req.prompt),
                    decode_tokens=req.max_new_tokens,
                    quality_floor=req.quality_floor)
                print(f"STALLED {req.rid}[{req.sensitivity}]: {dec.reason}")
            from repro.fleet import peek_slot_meta
            for src, blob in fleet.orphans:
                meta = peek_slot_meta(blob)
                print(f"STALLED {meta['rid']}[{meta['sensitivity']}]: "
                      f"orphaned snapshot from {src}, no eligible engine")
            raise SystemExit(1)

    for rid in sorted(tickets):
        t = tickets[rid]
        route = "->".join(fleet.placements.get(rid, [])) or "-"
        out = t.output
        print(f"{rid}[{t.spec.sensitivity:12s} p{t.spec.priority:<3d} "
              f"{t.state.value:9s}] via {route}: "
              f"{out[:8]}{'...' if len(out) > 8 else ''}")
    if autoscaler is not None:
        # idle ticks let the autoscaler drain + retire what it spawned
        for _ in range(16):
            if not autoscaler.spawned:
                break
            fleet.step()
    preempted = [ev for ev in fleet.telemetry.events
                 if getattr(ev, "dst", None) == "migrating"
                 and "preempted" in ev.reason]
    for ev in preempted:
        print(f"preempted {ev.rid} on {ev.engine}: {ev.reason}")
    for ev in fleet.telemetry.scale_events():
        print(f"scale {ev.action} {ev.engine} at t={ev.t:.3f} "
              f"(pool {ev.engines}): {ev.reason}")
    for ev in fleet.telemetry.quality_events():
        print(f"quality {ev.direction}shift {ev.rid} "
              f"{ev.src_tier}->{ev.dst_tier} (q={ev.quality:.2f}): "
              f"{ev.reason}")
    print(json.dumps(fleet.telemetry.summary(), indent=1))
    for dname, spec in fleet.spec_controllers.items():
        print(f"speculative tier {dname}->{spec.verify.name}: "
              f"{json.dumps(spec.stats.summary())}")
    print(f"simulated wire time: {fleet.fabric.clock():.3f}s "
          f"({len(fleet.telemetry.migrations)} live migrations)")
    if args.prefix_cache:
        p = fleet.telemetry.summary()["prefix"]
        print(f"prefix cache: {p['hits']} hits / {p['misses']} misses "
              f"(hit rate {p['hit_rate']:.0%}), "
              f"{p['bytes_saved']} KV bytes saved, "
              f"{p['evictions']} evictions")
    if args.trace_out and fleet.tracer is not None:
        fleet.tracer.close_open(reason="run complete")
        fleet.tracer.export_chrome(args.trace_out)
        print(f"trace: {args.trace_out} ({len(fleet.tracer.spans)} spans"
              f" -- open in Perfetto / chrome://tracing)")
    if args.otlp_out and fleet.tracer is not None:
        fleet.tracer.close_open(reason="run complete")
        fleet.tracer.export_otlp(args.otlp_out)
        print(f"otlp: {args.otlp_out} ({len(fleet.tracer.spans)} spans"
              f" -- OTLP-JSON, collector-ready)")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(fleet.telemetry.prometheus_text())
        print(f"metrics: {args.metrics_out}")


if __name__ == "__main__":
    main()
