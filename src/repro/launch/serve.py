"""Serving driver: batched requests through the full MVVM stack --
engine + privacy daemon + validation + (optional) speculation.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch llama-1.5b --tiny --requests 8 --max-new 24 --validate
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-1.5b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.7)
    ap.add_argument("--validate", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get
    from repro.configs.tiny import make_tiny
    from repro.core.daemon import PrivacyAwareDaemon
    from repro.core.validation import ValidationFramework
    from repro.models.init import init_params
    from repro.serving.engine import Engine, Request

    cfg = get(args.arch)
    if args.tiny:
        cfg = make_tiny(cfg)
    params = init_params(cfg, jax.random.key(args.seed))
    engine = Engine(cfg, params, slots=args.slots, max_len=args.max_len,
                    seed=args.seed)
    daemon = PrivacyAwareDaemon()
    vf = ValidationFramework() if args.validate else None

    rng = np.random.default_rng(args.seed)
    sensitivities = ["public", "personal", "confidential"]
    reqs = [Request(rid=f"r{i}",
                    prompt=rng.integers(50, cfg.vocab_size, 8),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature, top_k=16,
                    sensitivity=sensitivities[i % 3])
            for i in range(args.requests)]

    t0 = time.time()
    for r in reqs:
        d = daemon.decide(sensitivity=r.sensitivity, cfg=cfg,
                          prefill_tokens=len(r.prompt),
                          decode_tokens=r.max_new_tokens,
                          workspace_bytes=10 ** 7)
        print(f"{r.rid}[{r.sensitivity}] -> {d.target} ({d.reason})")
    # drive the engine directly: admit while slots free, then batch-step
    # (Engine.run() is deprecated in favor of exactly this loop)
    pending = list(reqs)
    outs: dict[str, list[int]] = {}
    while pending or engine.requests:
        while pending and engine.add_request(pending[0]):
            outs[pending[0].rid] = pending[0].output
            pending.pop(0)
        if engine.requests:
            engine.step()
    dt = time.time() - t0
    total_toks = sum(len(v) for v in outs.values())
    for rid, toks in sorted(outs.items()):
        line = f"{rid}: {toks}"
        if vf is not None:
            rep = vf.validate_post_hoc(toks)
            if rep.intervened:
                line += f"  [BLOCKED @{rep.halt_position}: " + ",".join(
                    v.kind for v in rep.verdicts if not v.ok) + "]"
        print(line)
    print(f"{total_toks} tokens in {dt:.2f}s "
          f"({total_toks/dt:.1f} tok/s on {jax.default_backend()})")


if __name__ == "__main__":
    main()
