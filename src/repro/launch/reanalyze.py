"""Re-derive roofline JSONs from saved HLO dumps -- no recompilation.

    PYTHONPATH=src python -m repro.launch.reanalyze \
        [--hlo results/hlo] [--out results/dryrun]
"""

import argparse
import glob
import json
import os

from repro import compression


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hlo", default="results/hlo")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    from repro.configs import SHAPES, get
    from repro.launch import hlo_analysis, roofline

    for f in sorted(glob.glob(os.path.join(args.hlo, "*.hlo.zst"))):
        base = os.path.basename(f)[:-len(".hlo.zst")]
        arch, shape_name, meshk = base.split("__")
        jpath = os.path.join(args.out, f"{base}.json")
        old = json.load(open(jpath)) if os.path.exists(jpath) else {}
        txt = compression.decompress(open(f, "rb").read()).decode()
        cost = hlo_analysis.analyze(txt)
        cfg = get(arch)
        shape = SHAPES[shape_name]
        chips = 512 if meshk == "multi" else 256
        n, na = cfg.param_count(), cfg.active_param_count()
        mf = roofline.model_flops(cfg, shape, n, na) / chips
        mb = roofline.model_bytes(cfg, shape, n, na, chips)
        coll = dict(cost.coll)
        coll["total"] = cost.coll_bytes
        rl = roofline.Roofline(
            arch=arch, shape=shape_name,
            mesh="2x16x16" if meshk == "multi" else "16x16",
            flops=cost.flops, hbm_bytes=cost.bytes,
            coll_bytes=cost.coll_bytes, coll_breakdown=coll,
            peak_memory_bytes=old.get("peak_memory_bytes", 0.0),
            model_flops=mf, model_bytes=mb).finalize()
        rec = {**old, **rl.to_dict()}
        with open(jpath, "w") as fh:
            json.dump(rec, fh, indent=1, default=str)
        print(f"{base}: mem={rl.memory_s:.4f}s comp={rl.compute_s:.4f}s "
              f"coll={rl.collective_s:.4f}s dom={rl.dominant} "
              f"frac={rl.roofline_fraction:.3f}")


if __name__ == "__main__":
    main()
