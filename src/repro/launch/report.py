"""Render §Dry-run / §Roofline markdown tables from results/dryrun JSONs,
per-request timelines from an exported Chrome trace, and per-tier SLO
tables from a fleet summary.

    PYTHONPATH=src python -m repro.launch.report [--dir results/dryrun]
    PYTHONPATH=src python -m repro.launch.report --trace TRACE_fleet.json
    PYTHONPATH=src python -m repro.launch.report --trace T.json --rid r3
    PYTHONPATH=src python -m repro.launch.report --slo summary.json
"""

import argparse
import glob
import json
import os


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PiB"


def load(d):
    rows = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        rows.append(json.load(open(f)))
    return rows


def roofline_table(rows, mesh="16x16"):
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL/HLO flops | roofline frac | peak mem/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"**{r['dominant']}** | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | "
            f"{fmt_bytes(r['peak_memory_bytes'])} |")
    return "\n".join(out)


def dryrun_table(rows):
    out = ["| arch | shape | mesh | compile s | HLO GFLOPs/dev | "
           "HBM GB/dev | coll GB/dev | ar/ag/rs/a2a/cp GB | "
           "args/dev | temps/dev |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        cb = r["coll_breakdown"]
        g = 1e9
        parts = "/".join(
            f"{cb.get(k, 0)/g:.2f}" for k in
            ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute"))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r.get('compile_s', 0):.0f} | {r['flops']/g:.1f} | "
            f"{r['hbm_bytes']/g:.2f} | {r['coll_bytes']/g:.3f} | "
            f"{parts} | {fmt_bytes(r.get('argument_size'))} | "
            f"{fmt_bytes(r.get('temp_size'))} |")
    return "\n".join(out)


def trace_timelines(trace: dict, rid: str | None = None) -> str:
    """ASCII per-request timelines from an exported Chrome trace.

    Spans are grouped by ``args.trace_id`` (the request id; engine
    tracks are ``engine:<name>``), children indented under parents by
    ``args.parent_id``, and each line shows start/duration (ms) plus the
    engine and the facts that explain the segment (reason, wire bytes,
    lossy)."""
    by_trace: dict[str, list[dict]] = {}
    for ev in trace.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        a = ev.get("args", {})
        tid = a.get("trace_id")
        if tid is None or (rid is not None and tid != rid):
            continue
        by_trace.setdefault(tid, []).append(ev)
    lines = []
    for tid in sorted(by_trace):
        evs = sorted(by_trace[tid], key=lambda e: (e["ts"],
                                                   e["args"]["span_id"]))
        children: dict = {}
        for ev in evs:
            children.setdefault(ev["args"].get("parent_id"),
                                []).append(ev)
        lines.append(f"== {tid} ==")

        def emit(parent, depth):
            for ev in children.get(parent, ()):
                a = ev["args"]
                extras = [a.get("engine") or ev.get("engine") or ""]
                for k in ("reason", "route_tier", "outcome", "state",
                          "wire_bytes", "lossy", "dst",
                          "time_to_useful_s", "wall_s", "cache_hit",
                          "promoted", "construct_s", "standby_build_s"):
                    if a.get(k) not in (None, "", False):
                        extras.append(f"{k}={a[k]}")
                lines.append(
                    f"  {'  ' * depth}{ev['name']:<12s} "
                    f"{ev['ts'] / 1e3:9.3f}ms +{ev['dur'] / 1e3:8.3f}ms"
                    f"  {' '.join(x for x in extras if x)}")
                emit(a["span_id"], depth + 1)

        emit(None, 0)
    return "\n".join(lines)


def slo_table(slo: dict) -> str:
    out = ["| tier | requests | time at tier s | completed | "
           "availability | p50 | p95 | p99 |",
           "|---|---|---|---|---|---|---|---|"]
    for name, row in sorted(slo.items()):
        out.append(
            f"| {name or '(untiered)'} | {row['requests']} | "
            f"{row['time_at_tier_s']:.4f} | {row['completed']} | "
            f"{row['availability']:.4f} | {row['latency_p50']:.4f} | "
            f"{row['latency_p95']:.4f} | {row['latency_p99']:.4f} |")
    return "\n".join(out)


def prefix_line(prefix: dict | None) -> str:
    """One-line prefix-cache digest from a fleet summary's ``prefix``
    block; empty when the run never armed the cache."""
    if not prefix or not (prefix.get("hits") or prefix.get("misses")):
        return ""
    return (f"\nPrefix cache: hit rate {prefix['hit_rate']:.0%} "
            f"({prefix['hits']} hits / {prefix['misses']} misses), "
            f"{fmt_bytes(prefix['bytes_saved'])} KV saved, "
            f"{prefix['evictions']} evictions")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--section", choices=["roofline", "dryrun", "both"],
                    default="both")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="render per-request timelines from an exported "
                         "Chrome trace JSON instead of the tables")
    ap.add_argument("--rid", default=None,
                    help="with --trace: only this request's timeline")
    ap.add_argument("--slo", default=None, metavar="PATH",
                    help="render the per-tier SLO table from a fleet "
                         "summary JSON (or a bare summary()['slo'] dump)")
    args = ap.parse_args()
    if args.trace:
        print(trace_timelines(json.load(open(args.trace)), args.rid))
        return
    if args.slo:
        doc = json.load(open(args.slo))
        print("### Per-tier SLO\n")
        print(slo_table(doc.get("slo", doc)))
        print(prefix_line(doc.get("prefix")))
        return
    rows = load(args.dir)
    if args.section in ("roofline", "both"):
        print("### Roofline (single pod 16x16, per-device terms)\n")
        print(roofline_table(rows, "16x16"))
        print("\n### Roofline (multi-pod 2x16x16)\n")
        print(roofline_table(rows, "2x16x16"))
    if args.section in ("dryrun", "both"):
        print("\n### Dry-run raw (both meshes)\n")
        print(dryrun_table(rows))


if __name__ == "__main__":
    main()
