"""Render §Dry-run / §Roofline markdown tables from results/dryrun JSONs.

    PYTHONPATH=src python -m repro.launch.report [--dir results/dryrun]
"""

import argparse
import glob
import json
import os


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PiB"


def load(d):
    rows = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        rows.append(json.load(open(f)))
    return rows


def roofline_table(rows, mesh="16x16"):
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL/HLO flops | roofline frac | peak mem/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"**{r['dominant']}** | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | "
            f"{fmt_bytes(r['peak_memory_bytes'])} |")
    return "\n".join(out)


def dryrun_table(rows):
    out = ["| arch | shape | mesh | compile s | HLO GFLOPs/dev | "
           "HBM GB/dev | coll GB/dev | ar/ag/rs/a2a/cp GB | "
           "args/dev | temps/dev |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        cb = r["coll_breakdown"]
        g = 1e9
        parts = "/".join(
            f"{cb.get(k, 0)/g:.2f}" for k in
            ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute"))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r.get('compile_s', 0):.0f} | {r['flops']/g:.1f} | "
            f"{r['hbm_bytes']/g:.2f} | {r['coll_bytes']/g:.3f} | "
            f"{parts} | {fmt_bytes(r.get('argument_size'))} | "
            f"{fmt_bytes(r.get('temp_size'))} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--section", choices=["roofline", "dryrun", "both"],
                    default="both")
    args = ap.parse_args()
    rows = load(args.dir)
    if args.section in ("roofline", "both"):
        print("### Roofline (single pod 16x16, per-device terms)\n")
        print(roofline_table(rows, "16x16"))
        print("\n### Roofline (multi-pod 2x16x16)\n")
        print(roofline_table(rows, "2x16x16"))
    if args.section in ("dryrun", "both"):
        print("\n### Dry-run raw (both meshes)\n")
        print(dryrun_table(rows))


if __name__ == "__main__":
    main()
