"""Training driver: real end-to-end training with checkpoint/restart.

    PYTHONPATH=src python -m repro.launch.train \
        --arch llama-1.5b --tiny --steps 200 --batch 8 --seq 128 \
        --ckpt-dir /tmp/run1 [--resume]

On a TPU fleet the same driver runs under the production mesh
(--mesh single|multi); on CPU it uses whatever devices exist.  Fault
tolerance: checkpoints every --ckpt-every steps (atomic, GC'd); restart
resumes from the latest step including the data-pipeline cursor
(stateless pipeline: step index is the full cursor).  Elastic restore:
checkpoints restore onto a different mesh via per-leaf resharding
(checkpoint/disk.py).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-1.5b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    from repro.checkpoint import disk
    from repro.configs import get
    from repro.configs.tiny import make_tiny
    from repro.data.pipeline import DataConfig, Pipeline
    from repro.models.init import count_params, init_params
    from repro.optim.adamw import AdamWConfig, init_opt_state
    from repro.training.train import TrainConfig, make_train_step

    cfg = get(args.arch)
    if args.tiny:
        cfg = make_tiny(cfg)
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr, warmup_steps=args.steps // 10,
                              total_steps=args.steps),
        microbatches=args.microbatches)

    params = init_params(cfg, jax.random.key(args.seed))
    opt = init_opt_state(params)
    start = 0
    if args.resume and args.ckpt_dir:
        latest = disk.latest_step(args.ckpt_dir)
        if latest is not None:
            tree = disk.restore(args.ckpt_dir, latest,
                                {"params": params, "opt": opt})
            params, opt = tree["params"], tree["opt"]
            start = latest
            print(f"resumed from step {start}")

    print(f"training {cfg.name}: {count_params(cfg)/1e6:.1f}M params, "
          f"{args.steps} steps")
    pipe = Pipeline(DataConfig(cfg.vocab_size, args.seq, args.batch))
    step_fn = make_train_step(cfg, tcfg)

    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(step).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"ce {float(metrics['ce']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0):.1f}s)", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            disk.save(args.ckpt_dir, step + 1,
                      {"params": params, "opt": opt})
    if args.ckpt_dir:
        disk.save(args.ckpt_dir, args.steps, {"params": params, "opt": opt})
    print("done")


if __name__ == "__main__":
    main()
