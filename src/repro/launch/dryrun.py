import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, record memory/cost analyses + roofline terms.

MUST be run as a script/module (the XLA_FLAGS line above executes before
any jax import).  One cell:

    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch stablelm-12b --shape decode_32k --mesh single

Full sweep (subprocess per cell so device/compile state can't leak):

    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
"""

import argparse
import json
import subprocess
import sys
import time


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    from repro.configs import SHAPES, entry, get
    from repro.launch import roofline, steps
    from repro.launch.mesh import make_production_mesh

    cfg = get(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    mesh_name = "2x16x16" if multi_pod else "16x16"

    t0 = time.time()
    fn, args = steps.build(cfg, shape, mesh)
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    # persist the optimized HLO so analysis iterations don't recompile
    from repro import compression
    os.makedirs("results/hlo", exist_ok=True)
    hlo_path = (f"results/hlo/{arch}__{shape_name}__"
                f"{'multi' if multi_pod else 'single'}.hlo.zst")
    with open(hlo_path, "wb") as f:
        f.write(compression.compress(compiled.as_text().encode(), level=9))

    mem = compiled.memory_analysis()
    print(f"== {arch} x {shape_name} on {mesh_name} ==")
    print("memory_analysis:", mem)
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    print("cost_analysis: flops=%.3e bytes=%.3e"
          % (cost.get("flops", 0), cost.get("bytes accessed", 0)))

    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    rl = roofline.extract(
        compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
        chips=chips, cfg=cfg, shape_spec=shape, n_params=n_params,
        n_active=n_active)
    rec = rl.to_dict()
    rec.update(
        lower_s=t_lower, compile_s=t_compile, chips=chips,
        n_params=n_params, n_active=n_active,
        argument_size=getattr(mem, "argument_size_in_bytes", None),
        output_size=getattr(mem, "output_size_in_bytes", None),
        temp_size=getattr(mem, "temp_size_in_bytes", None),
        generated_code_size=getattr(mem, "generated_code_size_in_bytes",
                                    None),
    )
    print("roofline: compute=%.4fs memory=%.4fs collective=%.4fs "
          "dominant=%s useful=%.2f frac=%.3f"
          % (rl.compute_s, rl.memory_s, rl.collective_s, rl.dominant,
             rl.useful_flops_ratio, rl.roofline_fraction))
    return rec


def all_cells():
    from repro.configs import SHAPES, entry, names
    for arch in names():
        if arch == "llama-1.5b":
            continue  # paper's own model, not an assigned cell
        e = entry(arch)
        for shape in e.shapes:
            yield arch, shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()

    if args.all:
        os.makedirs(args.out, exist_ok=True)
        cells = [(a, s, m)
                 for a, s in all_cells()
                 for m in (("single", "multi") if args.mesh == "both"
                           else (args.mesh,))]
        failures = []
        for arch, shape, meshk in cells:
            path = os.path.join(args.out, f"{arch}__{shape}__{meshk}.json")
            if os.path.exists(path):
                print("skip (cached):", path)
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", meshk,
                   "--out", args.out]
            print(">>", " ".join(cmd), flush=True)
            r = subprocess.run(cmd, timeout=args.timeout)
            if r.returncode != 0:
                failures.append((arch, shape, meshk))
                print("!! FAILED", arch, shape, meshk, flush=True)
        print("failures:", failures)
        sys.exit(1 if failures else 0)

    rec = run_cell(args.arch, args.shape, args.mesh == "multi")
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(
        args.out, f"{args.arch}__{args.shape}__"
        f"{'multi' if args.mesh == 'multi' else 'single'}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    print("wrote", path)


if __name__ == "__main__":
    main()
