"""Roofline-term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``; XLA reports
them for the per-device SPMD module, so terms divide by *one* chip's
peak -- the "chips x" in the denominator is already folded in by SPMD
partitioning.  collective_bytes is parsed from the optimized HLO text:
the sum of result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (per device, i.e. the
bytes this chip injects into the interconnect fabric).

Hardware constants: TPU v5e-class -- 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (3D ring: ~2 concurrently usable links per collective
phase is folded into LINK_BW_EFF).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, asdict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(sig: str) -> int:
    """Bytes of all array shapes in an HLO result signature."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind result bytes summed over the module."""
    out = {k: 0 for k in _COLLECTIVES}
    out["total"] = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
                     r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                     r"collective-permute)(?:-start|-done)?\(", line)
        if not m:
            continue
        if "-done(" in line:
            continue  # avoid double counting start/done pairs
        b = _shape_bytes(m.group(1))
        out[m.group(2)] += b
        out["total"] += b
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device HLO bytes accessed
    coll_bytes: float            # per-device collective bytes
    coll_breakdown: dict
    peak_memory_bytes: float
    model_flops: float           # 6*N*D (train) / 2*N_active*tokens (decode)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    def finalize(self) -> "Roofline":
        self.compute_s = self.flops / PEAK_FLOPS
        self.memory_s = self.hbm_bytes / HBM_BW
        self.collective_s = self.coll_bytes / LINK_BW
        return self

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    model_bytes: float = 0.0     # analytic minimal HBM stream (see below)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs * chips): remat & padding waste."""
        return self.model_flops / max(self.flops, 1.0)

    @property
    def useful_bytes_ratio(self) -> float:
        return self.model_bytes / max(self.hbm_bytes, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """useful-time / bound-time.

        Useful time is the larger of the two irreducible floors: the
        model FLOPs at peak and the minimal HBM stream (weights + caches
        + one activation pass) at full bandwidth -- decode is legitimately
        memory-bound, so scoring it on FLOPs alone would pin every
        serving cell at ~0."""
        useful_s = max(self.model_flops / PEAK_FLOPS,
                       self.model_bytes / HBM_BW)
        return useful_s / max(self.bound_s, 1e-12)

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(dominant=self.dominant,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction,
                 bound_s=self.bound_s)
        return d


def model_flops(cfg, shape, n_params: int, n_active: int) -> float:
    """Useful-work FLOPs for the cell, per device."""
    toks = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                 else 1)
    if shape.kind == "train":
        total = 6.0 * n_active * toks
    else:
        total = 2.0 * n_active * toks
    return total


def model_bytes(cfg, shape, n_params: int, n_active: int,
                chips: int) -> float:
    """Analytic minimal per-device HBM stream for the cell (the memory-
    roofline floor):

      weights : active params, bf16, one read per step; each device
                holds/streams its TP shard (1/16 of the model -- DP/pod
                replicas stream their own copy)
      caches  : decode reads its cache shard once; prefill writes it once
      acts    : train/prefill stream each activation slab a handful of
                times (fwd + remat + bwd ~ 3 passes x ~(4d+2ff_eff)
                bytes/token/layer); decode activations are negligible

    Deliberately an *envelope* (no optimizer traffic, no resharding):
    the fraction it induces is conservative."""
    tp = 16
    w = 2.0 * n_active / tp
    mesh_div = chips
    toks_dev = shape.global_batch * shape.seq_len / mesh_div
    L = max(cfg.num_layers, 1)
    if cfg.moe is not None:
        ff_eff = cfg.moe.top_k * cfg.moe.d_expert \
            + cfg.moe.num_shared * cfg.moe.d_expert
    else:
        ff_eff = cfg.d_ff
    act_per_tok_layer = 2.0 * (4 * cfg.d_model + 2 * ff_eff)
    # cache bytes over the fleet: full-length KV for "attn" layers,
    # window-bounded for "local", O(1) recurrent state for rwkv/mamba
    S, B = shape.seq_len, shape.global_batch
    cache = 0.0
    for ls in cfg.layer_specs():
        if ls.mixer == "attn":
            cache += 2.0 * B * S * cfg.num_kv_heads * cfg.head_dim * 2
        elif ls.mixer == "local":
            cache += 2.0 * B * min(ls.window, S) \
                * cfg.num_kv_heads * cfg.head_dim * 2
        elif ls.mixer == "rwkv":
            cache += 4.0 * B * cfg.rwkv_heads * cfg.rwkv_head_dim ** 2
        elif ls.mixer == "mamba":
            cache += 4.0 * B * cfg.d_inner * (cfg.mamba_d_state
                                              + cfg.mamba_d_conv)
    if cfg.cross_attention:
        cache += 2.0 * B * S * cfg.num_kv_heads * cfg.head_dim * 2 \
            * sum(b.repeats * len(b.layers) for b in cfg.blocks)
    cache /= mesh_div
    if shape.kind == "train":
        return w + 3.0 * toks_dev * L * act_per_tok_layer
    if shape.kind == "prefill":
        return w + toks_dev * L * act_per_tok_layer + cache
    # decode: weights + cache shard read once
    return w + cache


def extract(compiled, *, arch, shape, mesh_name, chips, cfg, shape_spec,
            n_params, n_active) -> Roofline:
    """Derive roofline terms from the compiled per-device SPMD module.

    Uses the trip-count-aware HLO analyzer (launch/hlo_analysis.py):
    XLA's cost_analysis() counts while bodies once, which would
    undercount scan-over-layers programs by orders of magnitude."""
    from repro.launch import hlo_analysis
    txt = compiled.as_text()
    cost = hlo_analysis.analyze(txt)
    try:
        mem = compiled.memory_analysis()
        peak = float(getattr(mem, "temp_size_in_bytes", 0)
                     + getattr(mem, "argument_size_in_bytes", 0)
                     + getattr(mem, "output_size_in_bytes", 0)
                     - getattr(mem, "alias_size_in_bytes", 0))
    except Exception:
        peak = 0.0
    mf = model_flops(cfg, shape_spec, n_params, n_active) / chips
    mb = model_bytes(cfg, shape_spec, n_params, n_active, chips)
    coll = dict(cost.coll)
    coll["total"] = cost.coll_bytes
    return Roofline(arch=arch, shape=shape, mesh=mesh_name,
                    flops=cost.flops, hbm_bytes=cost.bytes,
                    coll_bytes=cost.coll_bytes,
                    coll_breakdown=coll, peak_memory_bytes=peak,
                    model_flops=mf, model_bytes=mb).finalize()
