"""Trip-count-aware cost analysis over optimized HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts ``while`` bodies ONCE
(verified in tests/test_roofline.py), which silently undercounts any
scan-over-layers / microbatch-accumulation program by orders of
magnitude.  This module re-derives per-device FLOPs, HBM bytes and
collective bytes directly from ``compiled.as_text()``:

  * the module is split into named computations with per-op shapes;
  * ``while`` ops multiply their body's cost by the trip count parsed
    from the loop condition (scan lowering: `compare(iv, constant(N))`);
    nested whiles recurse;
  * FLOPs: 2 * prod(result dims) * prod(contracting dims) per dot
    (+ fused-computation dots);
  * HBM bytes use a *TPU memory-hierarchy model* (the CPU-lowered HLO
    materializes buffers a Pallas kernel would keep in VMEM):
      - dots, dynamic-(update-)slices (weight streams / KV caches),
        gathers/scatters and collectives ALWAYS count;
      - elementwise / fusion / broadcast / reduce ops INSIDE while
        bodies count only when the result exceeds the VMEM-residency
        threshold (128 MB) -- loop-blocked tile intermediates (flash
        softmax tiles, rwkv chunk states) live in VMEM on TPU, while
        layer-sized activation slabs (residual stream) still stream HBM;
      - `copy` never counts: XLA:CPU copies loop carries that TPU
        aliases in place.
    Top-level (non-loop) ops count fully.
  * collective bytes: result bytes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute, trip-multiplied.

Numbers are per-device: the text is the per-device SPMD module.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w{2,5})\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s+"
    r"([\w\-]+)\((.*)$")
_CALL_ATTR = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_ATTR = re.compile(r"condition=%?([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_info(sig: str) -> tuple[int, list[list[int]]]:
    """(total bytes, list of dims lists) for a result signature."""
    total = 0
    dims_all = []
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        ds = [int(d) for d in dims.split(",") if d]
        n = math.prod(ds) if ds else 1
        total += n * _DTYPE_BYTES[dt]
        dims_all.append(ds)
    return total, dims_all


@dataclass
class Op:
    name: str
    sig: str
    opcode: str
    rest: str
    bytes_: int
    dims: list
    stream_bytes: int = -1   # HBM-billable size (see _finalize_streams)
    dus_bytes: int = 0       # fusion wraps dynamic-update-slice(s)


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    table: dict = field(default_factory=dict)   # op name -> Op


def parse_computations(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    comment = re.compile(r"/\*.*?\*/")
    for line in text.splitlines():
        line = comment.sub("", line)
        s = line.strip()
        if " = " not in s:
            # computation header: %name (params...) -> result {
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{",
                         s)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, sig, opcode, rest = m.groups()
        b, dims = _shape_info(sig)
        op = Op(name, sig, opcode, rest, b, dims)
        cur.ops.append(op)
        cur.table[name] = op
    for c in comps.values():
        _finalize_streams(comps, c)
    return comps


_LOAD_XFORM_OPS = {"parameter", "constant", "get-tuple-element", "bitcast",
                   "reshape", "convert", "copy", "dynamic-slice", "slice",
                   "transpose", "tuple"}


def _finalize_streams(comps: dict, comp: Computation):
    """stream_bytes: what an op actually pulls from HBM when consumed.

    XLA:CPU has no native bf16 GEMM, so it materializes f32 copies of
    bf16 weights (convert fusions) -- on TPU the MXU consumes bf16
    directly.  Similarly, scan lowering wraps `dynamic-slice(+convert)`
    of the stacked per-layer weight/cache buffers into fusions whose
    *operand* is the full stack; only the slice streams.  A fusion built
    purely from load-transform ops is billed at the smallest
    slice/input size instead of its (possibly upcast) result size."""
    for op in comp.ops:
        op.stream_bytes = op.bytes_
        if op.opcode == "convert":
            op.stream_bytes = op.bytes_ // 2 if "f32" in op.sig else \
                op.bytes_
        if op.opcode != "fusion":
            continue
        m = _CALL_ATTR.search(op.rest)
        sub = comps.get(m.group(1)) if m else None
        if sub is None:
            continue
        # fusion wrapping dynamic-update-slice(s): bill 2x the update
        # (flash/loop accumulators update in place; the aliased buffer
        # itself is not re-streamed -- matches plain-DUS billing)
        dus = [s for s in sub.ops if s.opcode == "dynamic-update-slice"]
        if dus:
            total = 0
            for s in dus:
                names = _OPERANDS.findall(s.rest.split(")")[0] + ")")
                upd = sub.table.get(names[1]) if len(names) > 1 else None
                total += 2 * (upd.bytes_ if upd else s.bytes_)
            op.dus_bytes = max(total, 1)
            continue
        if any(s.opcode not in _LOAD_XFORM_OPS for s in sub.ops):
            continue
        # pure load-transform: stream the narrowest realized form
        slices = [s.bytes_ for s in sub.ops
                  if s.opcode in ("dynamic-slice", "slice")]
        cand = slices + [op.bytes_]
        op.stream_bytes = min(c for c in cand if c > 0) \
            if any(c > 0 for c in cand) else op.bytes_


_TRIPS_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _trip_count(op_rest: str, cond: Computation | None) -> int:
    """Prefer XLA's known_trip_count backend_config; else max int constant
    in the loop condition (scan lowering: iv < N)."""
    m = _TRIPS_RE.search(op_rest)
    if m:
        return int(m.group(1))
    best = 1
    if cond is not None:
        for op in cond.ops:
            if op.opcode == "constant":
                mm = re.search(r"constant\((-?\d+)\)",
                               "constant(" + op.rest)
                if mm:
                    best = max(best, int(mm.group(1)))
    return best


def _dot_flops(comp: Computation, op: Op) -> float:
    m = _CONTRACT.search(op.rest)
    contract = [int(x) for x in m.group(1).split(",") if x] if m else []
    opnames = _OPERANDS.findall(op.rest.split("),")[0] + ")")
    lhs = comp.table.get(opnames[0]) if opnames else None
    k = 1
    if lhs is not None and lhs.dims:
        for c in contract:
            if c < len(lhs.dims[0]):
                k *= lhs.dims[0][c]
    out_elems = math.prod(op.dims[0]) if op.dims else 1
    return 2.0 * out_elems * max(k, 1)


_ZERO_COST = {"parameter", "constant", "tuple", "get-tuple-element",
              "bitcast", "reshape", "after-all", "iota",
              "partition-id", "replica-id", "rng-bit-generator"}


def _operand_bytes(comp: Computation, op: Op, limit: int = 8) -> int:
    names = _OPERANDS.findall(op.rest.split(")")[0] + ")")
    total = 0
    for n in names[:limit]:
        o = comp.table.get(n)
        if o is not None:
            total += o.stream_bytes if o.stream_bytes >= 0 else o.bytes_
    return total


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll: dict = None

    def __post_init__(self):
        if self.coll is None:
            self.coll = {k: 0.0 for k in COLLECTIVES}

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k in COLLECTIVES:
            self.coll[k] += other.coll[k] * mult


VMEM_THRESHOLD = 128 * 2 ** 20   # loop intermediates above this spill


def _comp_cost(comps, name, memo, in_loop=False) -> Cost:
    key = (name, in_loop)
    if key in memo:
        return memo[key]
    comp = comps.get(name)
    cost = Cost()
    memo[key] = cost
    if comp is None:
        return cost
    for op in comp.ops:
        oc = op.opcode
        if oc in _ZERO_COST or oc == "copy":
            continue
        if oc == "while":
            body = _CALL_ATTR.search(op.rest)
            cond = _COND_ATTR.search(op.rest)
            cond_comp = comps.get(cond.group(1)) if cond else None
            trips = _trip_count(op.rest, cond_comp)
            if body:
                cost.add(_comp_cost(comps, body.group(1), memo,
                                    in_loop=True),
                         mult=max(trips, 1))
            continue
        if oc in ("call", "conditional", "async-start"):
            for cn in _CALL_ATTR.findall(op.rest):
                cost.add(_comp_cost(comps, cn, memo, in_loop=in_loop))
            continue
        if oc == "fusion":
            m = _CALL_ATTR.search(op.rest)
            fused_dots = False
            if m and m.group(1) in comps:
                sub = comps[m.group(1)]
                for sop in sub.ops:
                    if sop.opcode == "dot":
                        cost.flops += _dot_flops(sub, sop)
                        fused_dots = True
                    elif sop.opcode.startswith("convolution"):
                        cost.flops += 2.0 * (math.prod(sop.dims[0])
                                             if sop.dims else 1)
            if 0 <= op.stream_bytes < op.bytes_:
                continue  # pure load-transform: consumers bill the stream
            if op.dus_bytes:
                cost.bytes += op.dus_bytes
                continue
            if fused_dots or not in_loop or op.bytes_ > VMEM_THRESHOLD:
                cost.bytes += op.bytes_ + _operand_bytes(comp, op)
            continue
        if oc == "dot":
            cost.flops += _dot_flops(comp, op)
            cost.bytes += op.bytes_ + _operand_bytes(comp, op)
            continue
        if oc in COLLECTIVES or any(oc == c + "-start" for c in COLLECTIVES):
            base = oc.replace("-start", "")
            cost.coll[base] += op.bytes_
            cost.coll_bytes += op.bytes_
            cost.bytes += op.bytes_ + _operand_bytes(comp, op)
            continue
        if oc.endswith("-done"):
            continue
        if oc == "dynamic-update-slice":
            names = _OPERANDS.findall(op.rest.split(")")[0] + ")")
            upd = comp.table.get(names[1]) if len(names) > 1 else None
            cost.bytes += 2 * (upd.bytes_ if upd else op.bytes_)
            continue
        if oc in ("dynamic-slice", "slice", "gather", "scatter"):
            cost.bytes += 2 * op.bytes_
            continue
        # elementwise / broadcast / reduce / convert / everything else:
        # VMEM-resident inside loop bodies unless slab-sized
        if not in_loop or op.bytes_ > VMEM_THRESHOLD:
            cost.bytes += op.bytes_ + _operand_bytes(comp, op)
    return cost


def analyze(hlo_text: str) -> Cost:
    comps = parse_computations(hlo_text)
    entry_name = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry_name = m.group(1)
            break
    if entry_name is None:
        # fall back: computation named like main
        entry_name = next((n for n in comps if "main" in n),
                          next(iter(comps), ""))
    memo: dict = {}
    return _comp_cost(comps, entry_name, memo)
