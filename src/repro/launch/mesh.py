"""Production meshes.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") -- "pod"
crosses the inter-pod DCN/ICI boundary; batch shards over it, parameters
replicate over it (pure DP between pods; optionally int8-compressed
gradient sync, see optim/compression.py).

Functions, not module constants: importing this module must never touch
jax device state (the dry-run pins XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    assert len(devs) >= n, (
        f"need {n} devices for mesh {shape}; have {len(devs)} "
        "(dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count"
        "=512 before any jax import)")
    from jax.experimental import mesh_utils
    dm = mesh_utils.create_device_mesh(shape, devices=devs[:n])
    return jax.sharding.Mesh(dm, axes)


def make_debug_mesh(model: int = 1, data: int = 1):
    """Small mesh over however many (CPU) devices tests spawned."""
    return jax.make_mesh((data, model), ("data", "model"))
