"""Canonical step functions + sharding trees for launch/dry-run.

One builder per shape kind; each returns (jitted_fn, abstract_args) so
the dry-run can ``.lower(*args).compile()`` without allocating anything.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding as shd
from repro.configs.base import ModelConfig, ShapeSpec
from repro.configs.inputs import batch_specs, cache_specs_abstract
from repro.models import schema
from repro.models.init import abstract_params
from repro.models.model import cache_specs, forward
from repro.optim.adamw import AdamWConfig
from repro.training.train import TrainConfig, train_step


def merged_rules(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    return {**cfg.overrides, **shape.overrides}


def param_shardings(cfg: ModelConfig, mesh, rules):
    tree = schema.model_schema(cfg)
    specs = shd.tree_specs(tree, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def batch_shardings(batch, cfg, mesh, rules):
    logical = {
        "tokens": ("batch", None),
        "positions": ("batch", None),
        "frames": ("batch", None, None),
        "patch_embeds": ("batch", None, None),
        "enc_out": ("batch", None, None),
    }
    return {
        k: NamedSharding(mesh, shd.resolve(logical[k], mesh, v.shape, rules))
        for k, v in batch.items()
    }


def cache_shardings(caches, mesh, rules):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        cache_specs(caches, mesh, rules))


def opt_shardings(pshard, mesh):
    return {"mu": pshard, "nu": pshard,
            "step": NamedSharding(mesh, P())}


def abstract_opt(params_abs):
    return {"mu": jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), params_abs),
        "nu": jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), params_abs),
        "step": jax.ShapeDtypeStruct((), jnp.int32)}


def build_train(cfg: ModelConfig, shape: ShapeSpec, mesh,
                microbatches: int = 4):
    rules = merged_rules(cfg, shape)
    tcfg = TrainConfig(optimizer=AdamWConfig(),
                       microbatches=microbatches, remat=True)

    def step(params, opt_state, batch):
        p, o, m = train_step(params, opt_state, batch, cfg=cfg, tcfg=tcfg,
                             mesh=mesh, rules=rules)
        return p, o, m["loss"]

    params_abs = abstract_params(cfg)
    opt_abs = abstract_opt(params_abs)
    batch = batch_specs(cfg, shape)
    pshard = param_shardings(cfg, mesh, rules)
    in_shardings = (pshard, opt_shardings(pshard, mesh),
                    batch_shardings(batch, cfg, mesh, rules))
    out_shardings = (pshard, opt_shardings(pshard, mesh),
                     NamedSharding(mesh, P()))
    fn = jax.jit(step, in_shardings=in_shardings,
                 out_shardings=out_shardings, donate_argnums=(0, 1))
    return fn, (params_abs, opt_abs, batch)


def build_prefill(cfg: ModelConfig, shape: ShapeSpec, mesh):
    rules = merged_rules(cfg, shape)

    def step(params, batch, caches):
        logits, caches, _ = forward(params, batch, cfg=cfg, mode="prefill",
                                    caches=caches, mesh=mesh, rules=rules)
        return logits[:, -1], caches

    params_abs = abstract_params(cfg)
    batch = batch_specs(cfg, shape)
    caches = cache_specs_abstract(cfg, shape)
    pshard = param_shardings(cfg, mesh, rules)
    cshard = cache_shardings(caches, mesh, rules)
    in_shardings = (pshard,
                    batch_shardings(batch, cfg, mesh, rules), cshard)
    out_shardings = (NamedSharding(
        mesh, shd.resolve(("batch", "vocab"), mesh,
                          (shape.global_batch, cfg.padded_vocab), rules)),
        cshard)
    fn = jax.jit(step, in_shardings=in_shardings,
                 out_shardings=out_shardings, donate_argnums=(2,))
    return fn, (params_abs, batch, caches)


def build_decode(cfg: ModelConfig, shape: ShapeSpec, mesh):
    rules = merged_rules(cfg, shape)

    def step(params, batch, caches):
        logits, caches, _ = forward(
            params, {"tokens": batch["tokens"]}, cfg=cfg, mode="decode",
            caches=caches, positions=batch["positions"],
            mesh=mesh, rules=rules)
        return logits[:, 0], caches

    params_abs = abstract_params(cfg)
    batch = batch_specs(cfg, shape)
    caches = cache_specs_abstract(cfg, shape)
    pshard = param_shardings(cfg, mesh, rules)
    cshard = cache_shardings(caches, mesh, rules)
    in_shardings = (pshard,
                    batch_shardings(batch, cfg, mesh, rules), cshard)
    out_shardings = (NamedSharding(
        mesh, shd.resolve(("batch", "vocab"), mesh,
                          (shape.global_batch, cfg.padded_vocab), rules)),
        cshard)
    fn = jax.jit(step, in_shardings=in_shardings,
                 out_shardings=out_shardings, donate_argnums=(2,))
    return fn, (params_abs, batch, caches)


def build(cfg: ModelConfig, shape: ShapeSpec, mesh, **kw):
    if shape.kind == "train":
        return build_train(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh)
    return build_decode(cfg, shape, mesh)
