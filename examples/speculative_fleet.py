"""Speculative tier hand-off across the fleet: draft on edge, verify on
cloud, per request.

The scenario: a short-context edge box sits next to the user; an
attested long-context cloud pod is the quality tier.  Each greedy
request prefllls on the edge, its slot ships ONCE over the attested
wire (cache rows re-laid-out for the cloud's larger max_len), then the
edge free-runs gamma-token drafts that the cloud teacher-force verifies
-- committed output is bit-exactly what the cloud alone would produce,
while the cloud only spends verify bursts on it.  Confidential traffic
with no attested verify tier falls back to local-only drafting.

    PYTHONPATH=src python examples/speculative_fleet.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get
from repro.configs.tiny import make_tiny
from repro.core.attestation import TrustAuthority
from repro.core.daemon import CLOUD, EDGE, DeviceProfile
from repro.core.validation import MarkerValidator
from repro.fleet import EngineHandle, FleetController, RequestSpec
from repro.models.init import init_params
from repro.serving.engine import Engine

EDGE_LEN, CLOUD_LEN = 96, 256


def drain(engine, reqs):
    """Engine-level batch serve via the non-deprecated add/step loop."""
    pending, outs = list(reqs), {}
    while pending or engine.requests:
        while pending and engine.add_request(pending[0]):
            outs[pending[0].rid] = pending[0].output
            pending.pop(0)
        if engine.requests:
            engine.step()
    return outs


def main():
    cfg = make_tiny(get("llama-1.5b"))
    params = init_params(cfg, jax.random.key(0))

    def handles():
        return [
            EngineHandle("edge", Engine(cfg, params, slots=4,
                                        max_len=EDGE_LEN, seed=0), EDGE),
            EngineHandle("cloud", Engine(cfg, params, slots=4,
                                         max_len=CLOUD_LEN, seed=1),
                         CLOUD),
        ]

    rng = np.random.default_rng(0)
    prompts = [rng.integers(60, cfg.vocab_size, 8) for _ in range(6)]

    print("== speculative tier: acceptance vs drafter temperature ==")
    for temp in (0.0, 0.8, 1.5):
        fleet = FleetController(
            handles(), authority=TrustAuthority(),
            spec_tiers={"edge": "cloud"},
            spec_options={"gamma": 4, "drafter_temperature": temp,
                          "drafter_top_k": 16})
        reqs = [RequestSpec(rid=f"r{i}", prompt=p, max_new_tokens=16)
                for i, p in enumerate(prompts)]
        outs = fleet.run(reqs)
        st = fleet.spec_controllers["edge"].stats
        print(f"  drafter T={temp:3.1f}: acceptance "
              f"{st.acceptance_rate:5.1%} ({st.accepted}/{st.proposed}), "
              f"{st.rounds} rounds, {st.corrections} corrections, "
              f"hand-off {st.handoff_bytes / st.handoffs:.0f} B/slot "
              f"@ {st.handoff_wire_s * 1e3 / st.handoffs:.1f} ms wire")
        if temp == 0.0:
            baseline = outs

    # committed output is the cloud's own greedy output, bit-exactly:
    from repro.serving.engine import Request
    cloud = Engine(cfg, params, slots=4, max_len=CLOUD_LEN, seed=7)
    refs = drain(cloud, [Request(f"r{i}", p, max_new_tokens=16)
                         for i, p in enumerate(prompts)])
    assert all(baseline[r] == refs[r] for r in refs)
    print("  spec output == pure cloud-engine output: True "
          f"(edge max_len {EDGE_LEN} != cloud max_len {CLOUD_LEN})")

    print("\n== sensitivity gate: unattested verify tier ==")
    unattested_cloud = DeviceProfile("cloudX", peak_flops=197e12,
                                     hbm_bw=819e9, chips=8,
                                     attested=False)
    hs = handles()
    hs[1] = EngineHandle("cloud", hs[1].engine, unattested_cloud)
    fleet = FleetController(hs, authority=TrustAuthority(),
                            spec_tiers={"edge": "cloud"})
    conf = RequestSpec(rid="conf", prompt=prompts[0], max_new_tokens=12,
                       sensitivity="confidential")
    pub = RequestSpec(rid="pub", prompt=prompts[1], max_new_tokens=12)
    outs = fleet.run([conf, pub])
    st = fleet.spec_controllers["edge"].stats
    print(f"  confidential request stayed local "
          f"(local_fallbacks={st.local_fallbacks}, "
          f"placements={fleet.placements['conf']})")
    assert fleet.placements["conf"] == ["edge"]
    assert len(outs["conf"]) == 12

    print("\n== validators run on the committed stream ==")
    fleet = FleetController(
        handles(), authority=TrustAuthority(),
        spec_tiers={"edge": "cloud"},
        spec_options={"validators": [
            MarkerValidator("harmful_content", "harmful", range(10, 20))]})
    # a prompt soaked in harmful-marker ids makes the model emit them
    bad = RequestSpec(rid="bad",
                      prompt=np.asarray([12, 14, 16, 18, 12, 14, 16, 18]),
                      max_new_tokens=16)
    outs = fleet.run([bad])
    st = fleet.spec_controllers["edge"].stats
    print(f"  interventions={st.interventions}, "
          f"halted output length={len(outs['bad'])} (of 16)")


if __name__ == "__main__":
    main()
