"""Scenario 2 (the trader): speculative execution at both granularities.

  * token-level: a small draft model proposes, the target verifies in
    one wide pass -- output provably equals target-only decoding;
  * request-level: fast path commits immediately when the slow path's
    emerging prefix agrees (paper Table 2).

    PYTHONPATH=src python examples/speculative_serving.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get
from repro.configs.tiny import make_tiny
from repro.core.speculation import (SpeculativeExecutor,
                                    autoregressive_generate,
                                    speculative_generate)
from repro.models.init import init_params


def main():
    target = make_tiny(get("llama-1.5b"), d_model=64)
    draft = make_tiny(get("llama-1.5b"), d_model=32, repeats_cap=1)
    pt = init_params(target, jax.random.key(0))
    pd = init_params(draft, jax.random.key(1))
    prompt = np.arange(8)

    print("== token-level speculative decoding ==")
    out, stats = speculative_generate(pd, draft, pt, target, prompt,
                                      gamma=4, max_new=24)
    ref, ar_steps = autoregressive_generate(pt, target, prompt, max_new=24)
    assert out == ref
    print(f"output == target-only output: True")
    print(f"target forward passes: {stats.target_steps} vs {ar_steps} "
          f"autoregressive ({stats.tokens_per_target_step:.2f} tokens "
          f"per target step, acceptance {stats.acceptance_rate:.0%})")

    # upper bound with a perfectly-aligned draft
    _, s2 = speculative_generate(pt, target, pt, target, prompt, gamma=4,
                                 max_new=24)
    print(f"perfect-draft bound: {s2.tokens_per_target_step:.2f} "
          "tokens per target step")

    print("\n== request-level fast/slow speculation (trading) ==")
    ex = SpeculativeExecutor(agree_prefix=0.5)

    def fast_path():          # streamlined model, first signals only
        time.sleep(0.02)
        return [10, 20, 30, 40]

    def slow_path_agrees():   # full market depth, same conclusion
        time.sleep(0.15)
        return [10, 20, 30, 41]

    out = ex.run(fast_path, slow_path_agrees)
    print(f"agree case: committed={out.committed.path} "
          f"latency={out.perceived_latency_s*1000:.0f}ms "
          f"speedup={out.speedup:.1f}x")

    def slow_path_diverges():
        time.sleep(0.15)
        return [99, 98, 97, 96]

    out = ex.run(fast_path, slow_path_diverges)
    print(f"diverge case: committed={out.committed.path} (trade revised "
          f"before exposure), corrected={out.corrected}")


if __name__ == "__main__":
    main()
