"""Quickstart: serve a model, snapshot its workspace mid-generation,
migrate it through an attested encrypted channel, and verify the
migrated agent continues bit-exactly.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get
from repro.configs.tiny import make_tiny
from repro.core import (AttestedSession, Attester, Channel, Migrator,
                        TrustAuthority, AgentWorkspace, capabilities,
                        measure_config)
from repro.models.init import init_params
from repro.serving.engine import Engine, Request


def main():
    # 1. a model + a serving engine ("edge device")
    cfg = make_tiny(get("llama-1.5b"))
    params = init_params(cfg, jax.random.key(0))
    edge = Engine(cfg, params, slots=2, max_len=64, seed=42)

    # 2. serve a request for a few steps
    req = Request("hello", prompt=np.arange(6), max_new_tokens=12,
                  temperature=0.7, top_k=8)
    edge.add_request(req)
    for _ in range(5):
        edge.step()
    print("tokens before migration:", req.output)

    # 3. attested handshake edge -> cloud (simulated network)
    auth = TrustAuthority()
    gid = measure_config(cfg)
    session = AttestedSession(
        Attester("edge-1", auth, gid, capabilities(cfg)),
        Attester("cloud-1", auth, gid, capabilities(cfg)),
        Channel(), whitelist={gid})

    # 4. migrate the live workspace (KV caches, rng, positions, ...)
    ws = AgentWorkspace.from_engine(edge, gid)
    cloud = Engine(cfg, params, slots=2, max_len=64, seed=999)
    cloud, report = Migrator().migrate(ws, session, cloud)
    print(f"migrated {report.raw_bytes}B raw -> {report.wire_bytes}B wire "
          f"in {report.total_s*1000:.1f}ms "
          f"(transfer {report.transfer_s*1000:.1f}ms simulated @1Gbps)")

    # 5. continue on the cloud engine
    out = list(req.output)
    while cloud.requests:
        out += list(cloud.step().values())
    print("tokens after migration: ", out)

    # 6. prove bit-exactness vs an unmigrated run
    ref_eng = Engine(cfg, params, slots=2, max_len=64, seed=42)
    ref = Request("hello", prompt=np.arange(6), max_new_tokens=12,
                  temperature=0.7, top_k=8)
    ref_eng.add_request(ref)
    for _ in range(12):
        ref_eng.step()
    assert out == ref.output, "migration changed the output!"
    print("bit-exact continuation verified.")


if __name__ == "__main__":
    main()
