"""End-to-end training driver: a ~100M llama-family model trained for a
few hundred steps with checkpointing + restart (fault-tolerance demo).

The paper is a serving system, so the canonical e2e driver is
launch/serve.py; this trainer exercises the training substrate
(train_4k's lowering path) at example scale.

CPU note: the default runs a ~2M-param model for 120 steps in ~2
minutes.  ``--full`` selects the true ~100M config (24L/640d) --
recommended on accelerators.

    PYTHONPATH=src python examples/train_100m.py [--full] [--resume]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.checkpoint import disk
from repro.configs.base import BlockDef, LayerSpec, ModelConfig
from repro.data.pipeline import DataConfig, Pipeline
from repro.models.init import count_params, init_params
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.training.train import TrainConfig, make_train_step

CFG_100M = ModelConfig(
    name="repro-100m", family="dense", d_model=640, num_heads=10,
    num_kv_heads=5, head_dim=64, d_ff=1792, vocab_size=8192,
    vocab_pad_multiple=128,
    blocks=(BlockDef((LayerSpec("attn", "dense"),), repeats=24),))

CFG_2M = ModelConfig(
    name="repro-2m", family="dense", d_model=128, num_heads=4,
    num_kv_heads=2, head_dim=32, d_ff=384, vocab_size=2048,
    vocab_pad_multiple=128,
    blocks=(BlockDef((LayerSpec("attn", "dense"),), repeats=4),))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train100m")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = CFG_100M if args.full else CFG_2M
    print(f"{cfg.name}: {count_params(cfg)/1e6:.1f}M params")
    tcfg = TrainConfig(optimizer=AdamWConfig(
        lr=3e-3, warmup_steps=10, total_steps=args.steps))

    params = init_params(cfg, jax.random.key(0))
    opt = init_opt_state(params)
    start = 0
    if args.resume:
        latest = disk.latest_step(args.ckpt_dir)
        if latest:
            tree = disk.restore(args.ckpt_dir, latest,
                                {"params": params, "opt": opt})
            params, opt, start = tree["params"], tree["opt"], latest
            print(f"resumed at step {start}")

    pipe = Pipeline(DataConfig(cfg.vocab_size, args.seq, args.batch))
    step_fn = make_train_step(cfg, tcfg)
    first = last = None
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(step).items()}
        params, opt, m = step_fn(params, opt, batch)
        loss = float(m["loss"])
        first = loss if first is None else first
        last = loss
        if step % 10 == 0:
            print(f"step {step:4d}  loss {loss:.4f}")
        if (step + 1) % 40 == 0:
            disk.save(args.ckpt_dir, step + 1,
                      {"params": params, "opt": opt})
            print(f"  checkpointed at {step+1} "
                  "(kill + --resume to test restart)")
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({'LEARNED' if last < first - 0.5 else 'check config'})")


if __name__ == "__main__":
    main()
