"""Scenario 3 (collaborative medical diagnosis): confidential placement
+ attested migration + parallel validation that intervenes mid-stream.

    PYTHONPATH=src python examples/validated_medical_agent.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get
from repro.configs.tiny import make_tiny
from repro.core.daemon import PrivacyAwareDaemon
from repro.core.validation import MEDICAL, ValidationFramework
from repro.models.init import init_params
from repro.serving.engine import Engine, Request


def main():
    cfg = make_tiny(get("llama-1.5b"))
    params = init_params(cfg, jax.random.key(0))

    # 1. the daemon pins patient data to hospital infrastructure
    daemon = PrivacyAwareDaemon()          # default: confidential stays
    dec = daemon.decide(sensitivity="confidential", cfg=get("llama-1.5b"),
                        prefill_tokens=200_000, decode_tokens=20_000,
                        workspace_bytes=10 ** 8)
    print(f"placement for confidential case: {dec.target} ({dec.reason})")

    hospital = PrivacyAwareDaemon(max_remote_sensitivity="confidential")
    dec = hospital.decide(sensitivity="confidential",
                          cfg=get("llama-1.5b"),
                          prefill_tokens=200_000, decode_tokens=20_000,
                          workspace_bytes=10 ** 8)
    print(f"with hospital private-cloud policy: {dec.target} "
          f"(speedup {dec.speedup:.1f}x)")

    # 2. diagnosis generation with in-stream validation
    engine = Engine(cfg, params, slots=1, max_len=96, seed=4)
    req = Request("dx-patient-7", np.arange(8), max_new_tokens=32,
                  temperature=0.8, top_k=16)
    engine.add_request(req)
    vf = ValidationFramework(stride=2)

    emitted = []

    def emit():
        if not engine.requests:
            return None
        toks = engine.step()
        t = toks.get("dx-patient-7")
        if t is None:
            return None
        emitted.append(t)
        # plant a synthetic medical-error marker to show intervention
        if len(emitted) == 9:
            return MEDICAL.start + 2
        return t

    tokens, report = vf.validate_stream(emit)
    if report.intervened:
        bad = [v for v in report.verdicts if not v.ok][0]
        print(f"\nvalidator '{bad.kind}' INTERVENED at position "
              f"{bad.position}: suggestion blocked before reaching "
              f"the physician ({len(tokens)} safe tokens kept)")
    print(f"validation mode: {report.mode}, wall {report.wall_s*1000:.0f}ms"
          f" (parallel with generation -- paper: +3-5% vs +18% serial)")


if __name__ == "__main__":
    main()
