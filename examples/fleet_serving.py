"""Scenario: a fleet spanning phone (unattested MCU-class), laptop edge
and cloud pod serves one request stream; the cloud node dies mid-decode
and every conversation continues, bit-identically, on the survivors.

    PYTHONPATH=src python examples/fleet_serving.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get
from repro.configs.tiny import make_tiny
from repro.core.attestation import TrustAuthority
from repro.core.daemon import CLOUD, EDGE, MCU
from repro.fleet import EngineHandle, FleetController
from repro.models.init import init_params
from repro.serving.engine import Engine, Request


def main():
    cfg = make_tiny(get("llama-1.5b"))
    params = init_params(cfg, jax.random.key(0))
    mk = lambda s: Engine(cfg, params, slots=3, max_len=64, seed=s)
    fleet = FleetController(
        [EngineHandle("phone", mk(0), MCU),       # no enclave: public only
         EngineHandle("laptop", mk(1), EDGE),
         EngineHandle("cloud", mk(2), CLOUD)],
        authority=TrustAuthority())

    rng = np.random.default_rng(7)
    sens = ["public", "personal", "confidential"]
    reqs = [Request(f"chat{i}", rng.integers(5, cfg.vocab_size, 6),
                    max_new_tokens=14, sensitivity=sens[i % 3])
            for i in range(8)]
    for r in reqs:
        fleet.submit(r)

    # everyone is mid-conversation...
    for _ in range(6):
        fleet.step()
    placed = {n: sorted(r.rid for r in h.engine.requests.values())
              for n, h in fleet.handles.items()}
    print("mid-decode placement:", placed)

    # ...when the cloud node disappears
    print("\n-- cloud node lost --")
    fleet.fail("cloud")
    outs = fleet.run()
    print(f"all {len(outs)} conversations finished on the survivors")

    for rid in sorted(fleet.done):
        req = fleet.done[rid]
        print(f"  {rid}[{req.sensitivity:12s}] "
              f"via {'->'.join(fleet.placements[rid])}")
    tel = fleet.telemetry.summary()
    print("\nfleet telemetry:", tel["fleet"])
    assert all("phone" not in fleet.placements[r.rid]
               for r in reqs if r.sensitivity != "public")
    print("policy held: nothing sensitive ever touched the phone")


if __name__ == "__main__":
    main()
