"""Scenario: a fleet spanning phone (unattested MCU-class), laptop edge
and cloud pod serves one request stream; the cloud node dies mid-decode
and every conversation continues, bit-identically, on the survivors.

Act two shows the request-lifecycle API: ``submit(RequestSpec)`` returns
a ``RequestTicket`` you can stream (``tokens()``), cancel, or block on
(``result()``); a high-priority arrival preempts the lowest-priority
slot *via the migration machinery* (parked with extract_slot/pack_slot,
resumed bit-identically when capacity frees).

    PYTHONPATH=src python examples/fleet_serving.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.configs.tiny import make_tiny
from repro.core.attestation import TrustAuthority
from repro.core.channel import NetworkCondition
from repro.core.daemon import CLOUD, EDGE, MCU
from repro.fleet import (Autoscaler, EngineHandle, EngineTemplate,
                         FleetController, QualityTier, RequestSpec,
                         RequestState, ScalePolicy)
from repro.models.init import init_params
from repro.optim.compression import dequantize_int8, quantize_int8
from repro.serving.engine import Engine
from repro.serving.paged import PagedEngine


def main():
    cfg = make_tiny(get("llama-1.5b"))
    params = init_params(cfg, jax.random.key(0))
    mk = lambda s: Engine(cfg, params, slots=3, max_len=64, seed=s)
    fleet = FleetController(
        [EngineHandle("phone", mk(0), MCU),       # no enclave: public only
         EngineHandle("laptop", mk(1), EDGE),
         EngineHandle("cloud", mk(2), CLOUD)],
        authority=TrustAuthority())

    rng = np.random.default_rng(7)
    sens = ["public", "personal", "confidential"]
    tickets = [fleet.submit(RequestSpec(
        rid=f"chat{i}", prompt=rng.integers(5, cfg.vocab_size, 6),
        max_new_tokens=14, sensitivity=sens[i % 3]))
        for i in range(8)]

    # everyone is mid-conversation...
    for _ in range(6):
        fleet.step()
    placed = {n: sorted(r.rid for r in h.engine.requests.values())
              for n, h in fleet.handles.items()}
    print("mid-decode placement:", placed)

    # ...when the cloud node disappears
    print("\n-- cloud node lost --")
    fleet.fail("cloud")
    while not all(t.done for t in tickets):
        fleet.step()
    print(f"all {len(tickets)} conversations finished on the survivors")

    for t in sorted(tickets, key=lambda t: t.rid):
        print(f"  {t.rid}[{t.spec.sensitivity:12s}] "
              f"via {'->'.join(fleet.placements[t.rid])}")
    tel = fleet.telemetry.summary()
    print("\nfleet telemetry:", tel["fleet"])
    assert all("phone" not in fleet.placements[t.rid]
               for t in tickets if t.spec.sensitivity != "public")
    print("policy held: nothing sensitive ever touched the phone")

    lifecycle_act(cfg, params)


def lifecycle_act(cfg, params):
    """Tickets, priorities, preemption-by-migration, cancellation."""
    print("\n-- act two: the request-lifecycle API --")
    rng = np.random.default_rng(11)
    fleet = FleetController(
        [EngineHandle("laptop",
                      Engine(cfg, params, slots=1, max_len=64, seed=4),
                      EDGE)],
        authority=TrustAuthority())

    batch = fleet.submit(RequestSpec(
        rid="batch-job", prompt=rng.integers(5, cfg.vocab_size, 6),
        max_new_tokens=20, priority=0))
    for _ in range(4):
        fleet.step()                  # the batch job is mid-decode...
    print(f"batch-job: {batch.state.value}, "
          f"{len(batch.tokens())} tokens streamed so far")

    # ...when an interactive request arrives at higher priority: the
    # batch slot is parked (extract_slot -> pack_slot, the migration
    # departure path) and the interactive one takes the engine
    chat = fleet.submit(RequestSpec(
        rid="chat", prompt=rng.integers(5, cfg.vocab_size, 5),
        max_new_tokens=8, priority=10))
    fleet.step()
    assert batch.state is RequestState.MIGRATING   # parked off-engine
    print(f"chat arrived at priority 10: batch-job is "
          f"{batch.state.value} (parked), chat is {chat.state.value}")
    print(f"chat result: {chat.result()}")

    # the parked slot resumes bit-identically and finishes
    out = batch.result()
    print(f"batch-job resumed and finished: {len(out)} tokens, "
          f"states {[ev.dst for ev in batch.events]}")

    # cancellation frees a slot immediately
    doomed = fleet.submit(RequestSpec(
        rid="doomed", prompt=rng.integers(5, cfg.vocab_size, 4),
        max_new_tokens=30))
    fleet.step()
    doomed.cancel()
    print(f"doomed: {doomed.state.value}; engine free again: "
          f"{fleet.handles['laptop'].engine.free_slots == [0]}")
    print("lifecycle telemetry:", fleet.telemetry.summary()["lifecycle"])

    autoscale_act(cfg, params)


def autoscale_act(cfg, params):
    """Elastic pool: a burst grows the fleet, idleness shrinks it --
    and scale-down drains via the migration path, never dropping work."""
    print("\n-- act three: elastic autoscaling --")
    rng = np.random.default_rng(23)
    fleet = FleetController(
        [EngineHandle("seed",
                      Engine(cfg, params, slots=2, max_len=64, seed=30),
                      EDGE)],
        authority=TrustAuthority(),
        autoscaler=Autoscaler(
            EngineTemplate(name="burst", profile=EDGE, slots=2,
                           max_len=64, seed=40),
            ScalePolicy(min_engines=1, max_engines=3,
                        scale_up_queue_depth=3, scale_down_util=0.3)))

    # burst arrival: eight requests hit a one-engine, two-slot pool
    burst = [fleet.submit(RequestSpec(
        rid=f"burst{i}", prompt=rng.integers(5, cfg.vocab_size, 6),
        max_new_tokens=10)) for i in range(8)]
    while not all(t.done for t in burst):
        fleet.step()
    grown = [ev for ev in fleet.telemetry.scale_events()
             if ev.action == "spawn"]
    print(f"burst of {len(burst)} served; pool grew by {len(grown)}:")
    for ev in grown:
        print(f"  spawn {ev.engine} (pool {ev.engines}): {ev.reason}")

    # idle: the pool drains back down to min_engines, each retired
    # engine leaving through drain() -- migration, not deletion
    while fleet.autoscaler.spawned:
        fleet.step()
    retired = [ev for ev in fleet.telemetry.scale_events()
               if ev.action == "retire"]
    print(f"idle again: pool shrank to {sorted(fleet.handles)} "
          f"({len(retired)} retires, all drained via migration)")
    placements = {t.rid: "->".join(fleet.placements[t.rid])
                  for t in burst}
    moved = {r: p for r, p in placements.items() if "->" in p}
    print(f"requests that rode a scale event: {moved or 'none'}")
    print("scaling telemetry:", {
        k: v for k, v in fleet.telemetry.summary()["lifecycle"].items()
        if k.startswith("scale")})

    quality_act(cfg, params)


def quality_act(cfg, params):
    """Quality tiers: a full-bf16 tier next to an int8 tier.  The full
    tier saturates, then loses its client link entirely -- and service
    stays up on the lite tier, every downshift a typed QualityEvent,
    floored requests waiting rather than degrading below contract."""
    print("\n-- act four: request-granular quality tiers --")

    def int8_round_trip(p):
        def f(w):
            if hasattr(w, "dtype") and jnp.issubdtype(w.dtype,
                                                      jnp.floating):
                q, s = quantize_int8(w)
                return dequantize_int8(q, s).astype(w.dtype)
            return w
        return jax.tree.map(f, p)

    FULL = QualityTier("full", 1.0, "bf16")
    LITE = QualityTier("lite", 0.6, "int8")
    fleet = FleetController(
        [EngineHandle("pod",
                      Engine(cfg, params, slots=1, max_len=64, seed=50),
                      CLOUD, tier=FULL),
         EngineHandle("edge-box",
                      Engine(cfg, int8_round_trip(params), slots=3,
                             max_len=64, seed=51),
                      EDGE, tier=LITE)],
        authority=TrustAuthority())

    rng = np.random.default_rng(31)
    mk = lambda rid, floor: fleet.submit(RequestSpec(
        rid=rid, prompt=rng.integers(5, cfg.vocab_size, 6),
        max_new_tokens=10, quality_floor=floor))
    flexible = [mk(f"flex{i}", 0.0) for i in range(3)]
    strict = mk("strict", 0.9)        # full tier or nothing

    while not all(t.done for t in flexible + [strict]):
        fleet.step()
    tiers_of = {t.rid: fleet.handles[fleet.placements[t.rid][-1]].tier.name
                for t in flexible + [strict]}
    print("placement tiers:", tiers_of)
    assert tiers_of["strict"] == "full", "floored work never degrades"
    for ev in fleet.telemetry.quality_events():
        print(f"  {ev.direction}shift {ev.rid} {ev.src_tier}->"
              f"{ev.dst_tier}: {ev.reason}")

    # the full tier's uplink dies: traffic continues on the lite tier
    print("-- full tier link down --")
    fleet.set_link("pod", NetworkCondition(up=False))
    survivors = [fleet.submit(RequestSpec(
        rid=f"cut{i}", prompt=rng.integers(5, cfg.vocab_size, 6),
        max_new_tokens=8)) for i in range(2)]
    while not all(t.done for t in survivors):
        fleet.step()
    for t in survivors:
        eng = fleet.placements[t.rid][-1]
        print(f"  {t.rid}: served on {eng} "
              f"(tier {fleet.handles[eng].tier.name}) despite the cut")
        assert fleet.handles[eng].tier.name == "lite"
    downs = fleet.telemetry.downshifts
    print(f"service never dropped a request; {downs} audited downshifts")

    prefix_act(cfg, params)


def prefix_act(cfg, params):
    """Warm-session routing: two tenants chat against paged engines
    with the prefix cache armed.  Each tenant's first request prefills
    its system prompt cold and donates the pages; follow-ups route to
    the engine already holding them (session affinity) and prefill only
    the fresh tail -- the router's capacity gate even discounts the
    shared pages."""
    print("\n-- act five: prefix caching & warm-session routing --")
    mk = lambda s: PagedEngine(cfg, params, rows=2, page_size=8,
                               max_len=64, seed=s, prefix_cache=True)
    fleet = FleetController(
        [EngineHandle("left", mk(60), EDGE),
         EngineHandle("right", mk(61), EDGE)],
        authority=TrustAuthority())

    rng = np.random.default_rng(41)
    system = {t: rng.integers(5, cfg.vocab_size, 16) for t in ("ada", "bob")}
    chat = lambda t, i: fleet.submit(RequestSpec(
        rid=f"{t}{i}", tenant=t,
        prompt=np.concatenate([system[t],
                               rng.integers(5, cfg.vocab_size, 4)]),
        max_new_tokens=6))

    # round one: each tenant's opener is a cold prefill somewhere
    openers = [chat("ada", 0), chat("bob", 0)]
    while not all(t.done for t in openers):
        fleet.step()
    homes = {t.rid[:3]: fleet.placements[t.rid][-1] for t in openers}
    print("cold openers placed:", homes)

    # round two: follow-ups reuse each tenant's cached system prompt
    follow = [chat("ada", 1), chat("bob", 1)]
    while not all(t.done for t in follow):
        fleet.step()
    for t in follow:
        eng = fleet.placements[t.rid][-1]
        print(f"  {t.rid}: routed to {eng} "
              f"(tenant home {homes[t.rid[:3]]})")
        assert eng == homes[t.rid[:3]], "affinity should pick the warm engine"
    p = fleet.telemetry.summary()["prefix"]
    print(f"prefix cache: {p['hits']} hits / {p['misses']} misses "
          f"(hit rate {p['hit_rate']:.0%}), {p['bytes_saved']} KV bytes "
          f"never recomputed")
    assert p["hits"] >= 2, "both follow-ups should hit"
    for h in fleet.handles.values():
        h.engine.check()              # shared-page refcounts audit clean
    print("allocator + refcount audits clean on both engines")


if __name__ == "__main__":
    main()
