"""Scenario 1 (the travel blogger): multi-tier replication with
failover + quality degradation + reconnect merge.

    PYTHONPATH=src python examples/resilient_failover.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get
from repro.configs.tiny import make_tiny
from repro.core.attestation import measure_config
from repro.core.replication import ReplicaTier, ReplicationManager
from repro.core.workspace import AgentWorkspace
from repro.models.init import init_params
from repro.serving.engine import Engine, Request


def main():
    cfg = make_tiny(get("llama-1.5b"))
    gid = measure_config(cfg)
    params = init_params(cfg, jax.random.key(0))
    mk = lambda s: Engine(cfg, params, slots=2, max_len=128, seed=s)
    mgr = ReplicationManager([
        ReplicaTier("cloud", mk(0), quality=1.0, functionality=1.0),
        ReplicaTier("edge", mk(1), quality=0.8, functionality=0.85),
        ReplicaTier("device", mk(2), quality=0.5, functionality=0.8),
    ])

    # drafting an article on the cloud tier, syncing replicas as we go
    cloud = mgr.tiers["cloud"].engine
    req = Request("article", np.arange(8), max_new_tokens=48)
    cloud.add_request(req)
    for _ in range(6):
        cloud.step()
        mgr.sync(AgentWorkspace.from_engine(cloud, gid))
    print(f"on cloud: {len(req.output)} tokens drafted; "
          f"incremental sync = {mgr.last_delta_fraction:.0%} of pages, "
          f"{mgr.sync_bytes_total}B total")

    # the bus enters the mountains
    print("\n-- network lost --")
    mgr.tiers["cloud"].cond.up = False
    tier, latency = mgr.failover("disconnect")
    print(f"failover -> {tier.name} in {latency*1000:.0f}ms "
          f"(quality {tier.quality:.0%}, paper budget: 200ms)")
    edge = tier.engine
    cont = next(iter(edge.requests.values()))
    for _ in range(6):
        edge.step()
    print(f"continued offline: {len(cont.output)} tokens")

    # bandwidth-starved roaming: degrade to the on-device model
    print("\n-- roaming at <1 Mbps --")
    mgr.tiers["edge"].cond.bandwidth_bps = 5e5
    mgr.tiers["device"].cond.bandwidth_bps = 5e5
    tier = mgr.pick_tier()
    print(f"placement under bandwidth limit: {tier.name} "
          f"(quality {tier.quality:.0%} -- graceful degradation)")

    # reconnect: merge diverged replicas with vector clocks
    print("\n-- reconnected --")
    mgr.tiers["cloud"].cond.up = True
    ws_local = AgentWorkspace.from_engine(edge, gid, node="edge")
    ws_cloud = AgentWorkspace.from_engine(cloud, gid, node="cloud")
    merged = mgr.merge_on_reconnect(ws_local, ws_cloud)
    print(f"merged vector clock: {merged.vclock.clocks}; "
          f"{len(merged.requests)} request(s) preserved")


if __name__ == "__main__":
    main()
